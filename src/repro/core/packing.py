"""Int4 nibble packing with a TPU-friendly layout.

GPU kernels (Marlin, FastGEMM) interleave int4 weights for ldmatrix/warp
lanes; the TPU analogue we chose avoids in-kernel gathers entirely.

The *layout unit* is fixed at 128 k-rows (one MXU contraction tile),
independent of the quantization scale group. Within each unit of 128
consecutive k-rows, packed byte-row ``b`` (of 64) holds

    low nibble  -> k = unit_start + b
    high nibble -> k = unit_start + 64 + b

so a kernel unpacks one unit with two int32 shift pairs and ONE concat on
the sublane (second-minor) dimension — natural k-order is reconstructed
without any lane permutation, and activations need no re-layout at all.
Decoupling layout from scale group means the same packed tensor serves
fine-grained (group=128/256/...) and coarse (per-channel) scales alike.

Packed shape: (K/2, N) int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LAYOUT_UNIT = 128  # k-rows per packing unit (= MXU tile on the K dim)


def layout_unit_for(K: int) -> int:
    """128 when possible; small-K fallback (smoke configs) packs K as one
    unit (K must be even)."""
    if K % LAYOUT_UNIT == 0:
        return LAYOUT_UNIT
    if K % 2 != 0:
        raise ValueError(f"K={K} must be even to nibble-pack")
    return K


def pack_int4(q: jax.Array, unit: int | None = None) -> jax.Array:
    """(K, N) int8 in [-8,7] -> (K/2, N) int8 nibble-packed (layout above)."""
    K, N = q.shape
    u = unit or layout_unit_for(K)
    h = u // 2
    q3 = q.reshape(K // u, u, N)
    lo = q3[:, :h, :].astype(jnp.int32) & 0xF
    hi = q3[:, h:, :].astype(jnp.int32) & 0xF
    packed = (lo | (hi << 4)).astype(jnp.uint8).astype(jnp.int8)
    return packed.reshape(K // 2, N)


def unpack_int4(packed: jax.Array, unit: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_int4` -> (K, N) int8, sign-extended."""
    Kh, N = packed.shape
    K = Kh * 2
    u = unit or layout_unit_for(K)
    h = u // 2
    p3 = packed.reshape(K // u, h, N).astype(jnp.int32)
    lo = jnp.left_shift(p3, 28) >> 28  # sign-extend low nibble
    hi = jnp.left_shift(p3, 24) >> 28  # sign-extend high nibble
    q3 = jnp.concatenate([lo, hi], axis=1)  # (K/u, u, N) natural order
    return q3.reshape(K, N).astype(jnp.int8)
