"""Quantization recipes: what to quantize, how, per layer.

A :class:`QuantSpec` describes one linear layer's scheme; a
:class:`QuantRecipe` maps layer-name patterns to specs (e.g. the paper's
LLaMA-3 recipe §5.6: W4A8 fine-grained everywhere, W8A8 fine-grained for
down-projections, QuaRot rotation on).
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Literal

Algo = Literal["rtn", "gptq", "awq", "smoothquant", "omniquant", "odyssey"]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """One linear layer's quantization scheme."""

    w_bits: int = 4
    a_bits: int = 8  # 16 => weight-only (activations stay bf16)
    group_size: int = 128  # -1 => coarse per-channel
    scale_mode: Literal["float", "integer"] = "integer"
    amplifier: int | str = 1024  # int power of two, or "heuristic"
    sym: bool = True
    algo: Algo = "rtn"
    rotate: bool = False  # QuaRot-style Hadamard rotation
    clip_ratio: float = 1.0

    @property
    def weight_only(self) -> bool:
        return self.a_bits >= 16

    @property
    def fine_grained(self) -> bool:
        return self.group_size > 0

    @property
    def name(self) -> str:
        g = f"g{self.group_size}" if self.fine_grained else "coarse"
        s = "IS" if self.scale_mode == "integer" else "FS"
        return f"W{self.w_bits}A{self.a_bits}-{g}-{s}-{self.algo}"


FP16 = None  # sentinel: layer not quantized

# The paper's main setting: fine-grained W4A8, symmetric, group 128, IS(1024)
W4A8_IS = QuantSpec()
W4A8_FS = QuantSpec(scale_mode="float")
W4A16_FG = QuantSpec(a_bits=16)  # Marlin-analog weight-only
# W8 scales are ~18x smaller than W4 (qmax 127 vs 7): a fixed alpha=1024
# underflows them, so W8A8+IS uses the Listing-1 heuristic plus 6 margin
# bits (see integer_scale.integerize; overflow audited in tests).
W8A8_FG = QuantSpec(w_bits=8, amplifier="heuristic+6")
W4A8_COARSE = QuantSpec(group_size=-1)  # Odyssey-style
W4A4_FG = QuantSpec(a_bits=4)  # Atom/QuaRot regime


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """Ordered (pattern -> spec) rules; first match wins; None = keep FP16.

    Patterns are fnmatch globs over slash-joined parameter paths, e.g.
    ``"*/mlp/down/*"`` or ``"*attn*"``.
    """

    rules: tuple[tuple[str, QuantSpec | None], ...] = (("*", W4A8_IS),)
    name: str = "w4a8-is"

    def spec_for(self, path: str) -> QuantSpec | None:
        for pat, spec in self.rules:
            if fnmatch.fnmatch(path, pat):
                return spec
        return None


# Paper §5.6 LLaMA-3 recipe: W8A8-FG for down projections, W4A8-FG elsewhere,
# rotation enabled (QuaRot), integer scale everywhere.
LLAMA3_RECIPE = QuantRecipe(
    rules=(
        ("*down*", dataclasses.replace(W8A8_FG, rotate=True)),
        ("*", dataclasses.replace(W4A8_IS, rotate=True)),
    ),
    name="llama3-w4a8-down8-quarot-is",
)

DEFAULT_RECIPE = QuantRecipe()
FLOAT_SCALE_RECIPE = QuantRecipe(rules=(("*", W4A8_FS),), name="w4a8-fs")
WEIGHT_ONLY_RECIPE = QuantRecipe(rules=(("*", W4A16_FG),), name="w4a16-fg")


def certify_recipe(recipe: QuantRecipe, dims: dict[str, int]) -> dict:
    """Static overflow verdict per (rule, contraction dim), no tensors.

    ``dims`` maps a label (e.g. "d_model", "d_ff") to a contraction size
    K. Returns {f"{pattern}@{label}": verdict} using the data-free scale
    contract of :func:`repro.analysis.certify.spec_verdict` — verdicts
    are "certified" / "capped-alpha" / "fallback" / "data-dependent"
    (heuristic amplifiers resolve per layer at quantization time) /
    "n/a" (no INT32 accumulation to certify). Quantization itself
    (qlinear.finish_quant) re-certifies with the layer's real scales.
    """
    from repro.analysis import certify

    out = {}
    for pat, spec in recipe.rules:
        for label, K in dims.items():
            out[f"{pat}@{label}"] = certify.spec_verdict(spec, int(K))
    return out
