"""Integer Scale (the paper's core contribution, §4).

Converts the per-group float scales of a fine-grained quantized weight to
integers via an *adaptive scale amplifier* alpha = 2^n (paper Listing 1),
enabling the group accumulation of Eq. 2 to stay entirely in INT32 with a
single final I32->F32 conversion:

    O_i = s_a_i * FLOAT( sum_g (X_g_i x W_g_i^T) * INT(s_g_i * alpha) ) / alpha

This module is a *free lunch*: it needs only the already-computed float
scales — no calibration data, no fine-tuning.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .quant import QWeight, qmax

DEFAULT_AMPLIFIER_EXP = 10  # alpha = 2^10 = 1024, the paper's default (§6.1)

# Largest legal amplifier exponent. alpha = 2^30 keeps int_scale =
# round(scale * alpha) representable in int32 for any scale < 2 and leaves
# one bit of headroom before the 2^31 accumulator limit; every clamp on the
# amplifier path MUST use this single bound (heuristic_amplifier_exp,
# heuristic_amplifier, integerize previously disagreed: 31 vs 30 vs 30).
MAX_AMPLIFIER_EXP = 30


# ---------------------------------------------------------------------------
# Adaptive scale amplifier (paper Listing 1)
# ---------------------------------------------------------------------------


def heuristic_amplifier_exp(
    scales: jax.Array, max_exp: int = MAX_AMPLIFIER_EXP
) -> jax.Array:
    """Paper Listing 1: smallest n such that min(scales) * 2^n >= 1; the
    amplifier used is then 2^(n-1)... — we follow the listing exactly:

        n, tmp = 0, scale_min
        while tmp < 1: tmp = scale_min * 2**n; n += 1
        amplifier = 2**(n-1)

    i.e. amplifier = 2^(n-1) with n the first exponent reaching >= 1.
    Implemented branchlessly with log2 so it jits.
    Returns the integer exponent (n-1).
    """
    smin = jnp.maximum(jnp.min(scales), 1e-30).astype(jnp.float32)
    # first n with smin * 2^n >= 1  <=>  n >= -log2(smin)
    n_first = jnp.ceil(-jnp.log2(smin))
    # Listing increments n once more after the condition holds, then uses
    # 2^(n-1): net effect amplifier exponent == n_first (when smin<1) else 0.
    exp = jnp.clip(n_first, 0, max_exp)
    return exp.astype(jnp.int32)


def heuristic_amplifier(scales: jax.Array) -> jax.Array:
    # exact integer 2^n (XLA's exp2 is an approximation on some backends —
    # a float path can return 2^27 - 56, which is not a power of two)
    exp = jnp.clip(heuristic_amplifier_exp(scales), 0, MAX_AMPLIFIER_EXP)
    return jnp.left_shift(jnp.int32(1), exp)


# ---------------------------------------------------------------------------
# Integer-scale weight bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ISWeight:
    """A fine-grained QWeight whose group scales were integerized.

    ``int_scale``: int32 (K/g, N) = round(float_scale * alpha), >= 1.
    ``alpha``: the amplifier (python int; folded into the epilogue as 1/alpha).
    ``qvalue``: same int8 codes as the parent QWeight.
    """

    qvalue: jax.Array  # int8 (K, N)
    int_scale: jax.Array  # int32 (K/g, N)
    alpha: int
    bits: int
    group_size: int

    @property
    def num_groups(self) -> int:
        return self.qvalue.shape[0] // self.group_size

    def effective_float_scale(self) -> jax.Array:
        """The float scales actually realized after integerization."""
        return self.int_scale.astype(jnp.float32) / float(self.alpha)

    def dequant(self) -> jax.Array:
        K, N = self.qvalue.shape
        g = self.group_size
        wq = self.qvalue.reshape(K // g, g, N).astype(jnp.float32)
        return (wq * self.effective_float_scale()[:, None, :]).reshape(K, N)


def integerize(
    qw: QWeight,
    amplifier: int | Literal["heuristic"] = 1024,
) -> ISWeight:
    """Convert float group scales -> integer scales (offline, free)."""
    if not qw.fine_grained:
        raise ValueError("Integer Scale targets fine-grained (group) scales; "
                         "use group_size>0")
    if isinstance(amplifier, str) and amplifier.startswith("heuristic"):
        # "heuristic" = paper Listing 1 exactly; "heuristic+k" adds k margin
        # bits (beyond-paper: Listing 1 only guarantees min(s)*alpha >= 1,
        # which leaves ~unit rounding granularity on the smallest scales —
        # extra bits buy precision while the overflow audit verifies safety).
        margin = int(amplifier.split("+")[1]) if "+" in amplifier else 0
        exp = int(heuristic_amplifier_exp(qw.scale)) + margin
        alpha = int(2 ** min(exp, MAX_AMPLIFIER_EXP))
    else:
        alpha = int(amplifier)
        if alpha < 1 or (alpha & (alpha - 1)) != 0:
            raise ValueError(f"amplifier must be a power of two, got {alpha}")
        if alpha > 2**MAX_AMPLIFIER_EXP:
            raise ValueError(
                f"amplifier {alpha} exceeds 2^{MAX_AMPLIFIER_EXP}; larger "
                "amplifiers are not int32-representable")
    int_scale = jnp.clip(
        jnp.round(qw.scale.astype(jnp.float32) * alpha), 1, 2**31 - 1
    ).astype(jnp.int32)
    _record_floor_hits(qw.scale, alpha)
    return ISWeight(qw.qvalue, int_scale, alpha, qw.bits, qw.group_size)


def _record_floor_hits(scales, alpha: int) -> None:
    """Count group scales so small that round(scale*alpha) clipped up to 1
    (each is a group whose effective scale integerization degraded to the
    1/alpha floor — a sign the amplifier is too small for this layer).
    Host-guarded: skipped when the scales are traced."""
    try:
        s = np.asarray(scales)
    except Exception:  # TracerArrayConversionError and friends
        return
    floor = obs.current_registry().counter(
        "int_scale_floor_hits_total",
        "group scales clipped up to int_scale=1 during integerization")
    hits = int((np.round(s.astype(np.float64) * alpha) < 1).sum())
    floor.inc(hits)


# ---------------------------------------------------------------------------
# Eq. 2 reference GEMM — integer scale, one final convert
# ---------------------------------------------------------------------------


def fg_gemm_integer_scale(
    xq: jax.Array,  # int8 (..., K)
    sa: jax.Array,  # f32 (..., 1) per-token scales
    isw: ISWeight,
) -> jax.Array:
    """Eq. 2: group partials stay int32, multiplied by int32 scales and
    accumulated in int32; ONE final convert + /alpha (folded into sa)."""
    K, N = isw.qvalue.shape
    g = isw.group_size
    G = K // g
    x3 = xq.reshape(*xq.shape[:-1], G, g)
    w3 = isw.qvalue.reshape(G, g, N)
    part = jax.lax.dot_general(
        x3, w3,
        dimension_numbers=(((x3.ndim - 1,), (1,)), ((x3.ndim - 2,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (G, ..., N)
    part = jnp.moveaxis(part, 0, -2)  # (..., G, N)
    acc_i32 = jnp.sum(part * isw.int_scale, axis=-2)  # int32 accumulation
    return acc_i32.astype(jnp.float32) * (sa / float(isw.alpha))


# ---------------------------------------------------------------------------
# Overflow audit (paper §B.4 / Fig. 8)
# ---------------------------------------------------------------------------


def overflow_bound(isw: ISWeight, a_bits: int = 8) -> int:
    """Worst-case |int32 accumulator| value: sum_g g_size*|x|max*|w|max*s_int.

    A static bound — if < 2^31 the layer can never overflow regardless of
    input. The paper instead verifies empirically (Fig. 8); we provide both.
    """
    per_group = (
        int(isw.group_size) * qmax(a_bits) * qmax(isw.bits)
    )  # max |partial|
    smax_per_group = jnp.sum(jnp.max(isw.int_scale, axis=1) * per_group)
    return int(smax_per_group)


def empirical_max_accum(xq, isw: ISWeight):
    """Max |int32 accumulator| actually reached for a given batch (Fig. 8),
    computed in NUMPY int64 (jax would silently truncate to int32 without
    the x64 flag, which could hide an overflow)."""
    import numpy as np

    K, N = isw.qvalue.shape
    g = isw.group_size
    G = K // g
    x3 = np.asarray(xq).reshape(-1, G, g).astype(np.int64)
    w3 = np.asarray(isw.qvalue).reshape(G, g, N).astype(np.int64)
    part = np.einsum("tgk,gkn->tgn", x3, w3)
    acc = np.cumsum(part * np.asarray(isw.int_scale, np.int64)[None],
                    axis=1)
    return np.max(np.abs(acc))


def would_overflow(isw: ISWeight, a_bits: int = 8) -> bool:
    return overflow_bound(isw, a_bits) >= 2**31


# ---------------------------------------------------------------------------
# §B.4 fallback: per-group de-amplification ("degraded" GEMM)
# ---------------------------------------------------------------------------


def fg_gemm_integer_scale_safe(xq, sa, isw: ISWeight):
    """Paper §B.4: for overflow-prone layers, remove the amplifier per group
    (extra per-group work, still integer-scale codes). Each group partial is
    scaled in int32 then immediately de-amplified into an f32 accumulator —
    trades the single-convert property for guaranteed no-overflow."""
    K, N = isw.qvalue.shape
    g = isw.group_size
    G = K // g
    x3 = xq.reshape(*xq.shape[:-1], G, g)
    w3 = isw.qvalue.reshape(G, g, N)
    part = jax.lax.dot_general(
        x3, w3,
        dimension_numbers=(((x3.ndim - 1,), (1,)), ((x3.ndim - 2,), (0,))),
        preferred_element_type=jnp.int32,
    )
    part = jnp.moveaxis(part, 0, -2)
    scaled = (part * isw.int_scale).astype(jnp.float32) / float(isw.alpha)
    return jnp.sum(scaled, axis=-2) * sa


# ---------------------------------------------------------------------------
# Analysis helpers (Fig. 4)
# ---------------------------------------------------------------------------


def bit_shift_required(scales: jax.Array) -> jax.Array:
    """Per-layer number of bit shifts the heuristic would use (Fig. 4b)."""
    return heuristic_amplifier_exp(scales)


def integerization_weight_mse(qw: QWeight, alpha: int) -> jax.Array:
    """Weight MSE between integer-scale and float-scale dequant (Fig. 4c)."""
    isw = integerize(qw, alpha)
    K, N = qw.qvalue.shape
    g = qw.group_size
    wq = qw.qvalue.reshape(K // g, g, N).astype(jnp.float32)
    d_f = wq * qw.scale[:, None, :]
    d_i = wq * isw.effective_float_scale()[:, None, :]
    return jnp.mean((d_f - d_i) ** 2)
