"""Quantized linear layer: spec declaration, offline quantization, apply.

This is the model-facing integration point of Integer Scale. A linear layer
in any architecture is declared through :func:`linear_specs`; depending on
the :class:`~repro.core.recipe.QuantSpec` attached to its path it becomes

  * FP (bf16) linear                          (spec is None)
  * fine/coarse W{4,8}A{4,8,16} quantized     (storage: packed int4 / int8)

Apply dispatches between the pure-jnp reference path (always available; used
for dry-run lowering and CPU tests) and the Pallas TPU kernels in
``repro.kernels`` (used on real TPUs; validated via interpret mode).
"""
from __future__ import annotations

import contextlib
from typing import Any, Literal

import jax
import jax.numpy as jnp

from repro import obs
from repro.nn import spec as S
from . import packing
from .integer_scale import integerize
from .quant import QWeight, quantize_activation, quantize_weight
from .recipe import QuantSpec

KernelMode = Literal["reference", "pallas", "pallas_interpret"]

# The mode is threaded explicitly: ModelConfig.kernel_mode -> apply_linear /
# expert_linear_apply -> here, and the serving engine sets it on its
# ServeConfig. ``kernel_mode`` below is the scoped default for scripts and
# benchmarks that don't thread a ``mode=`` kwarg.
_MODE_STACK: list[KernelMode] = []


@contextlib.contextmanager
def kernel_mode(mode: KernelMode):
    """Scoped default kernel mode for call sites that don't pass ``mode``.

    Prefer threading the mode explicitly (ModelConfig.kernel_mode /
    ServeConfig.kernel_mode / the ``mode=`` kwarg); this context manager
    exists so scripts and benchmarks keep a one-liner.
    """
    if mode not in ("reference", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown kernel mode {mode!r}")
    _MODE_STACK.append(mode)
    try:
        yield
    finally:
        _MODE_STACK.pop()


def current_kernel_mode() -> KernelMode:
    """Mode used when a call site passes ``mode=None``."""
    return _MODE_STACK[-1] if _MODE_STACK else "reference"


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _num_groups(K: int, group_size: int) -> int:
    return 1 if group_size <= 0 else K // group_size


def linear_specs(
    K: int,
    N: int,
    qspec: QuantSpec | None,
    axes: tuple[str | None, str | None],
    *,
    bias: bool = False,
    dtype=jnp.bfloat16,
) -> dict[str, S.ParamSpec]:
    """Parameter specs for one (possibly quantized) linear of shape (K, N).

    ``axes`` are the logical axes of (K, N) e.g. ("embed", "mlp").
    """
    ax_in, ax_out = axes
    out: dict[str, S.ParamSpec] = {}
    if qspec is None:
        out["w"] = S.w((K, N), (ax_in, ax_out), dtype=dtype)
    else:
        G = _num_groups(K, qspec.group_size)
        if qspec.w_bits == 4:
            out["qvalue"] = S.zeros((K // 2, N), (ax_in, ax_out), dtype=jnp.int8)
        elif qspec.w_bits == 8:
            out["qvalue"] = S.zeros((K, N), (ax_in, ax_out), dtype=jnp.int8)
        else:
            raise ValueError(f"unsupported w_bits={qspec.w_bits}")
        if (qspec.scale_mode == "integer" and not qspec.weight_only
                and qspec.fine_grained):
            out["scale"] = S.ones((G, N), (ax_in, ax_out), dtype=jnp.int32)
            # per-layer amplifier (supports the heuristic search, Listing 1)
            out["alpha"] = S.ones((), (), dtype=jnp.float32)
        else:
            out["scale"] = S.ones((G, N), (ax_in, ax_out), dtype=jnp.float32)
        if qspec.algo in ("awq", "smoothquant"):
            # per-in-channel activation compensation (x / pre_scale)
            out["pre_scale"] = S.ones((K,), (ax_in,), dtype=jnp.float32)
        if qspec.rotate:
            # QuaRot-style orthogonal rotation applied online to x
            out["rot"] = S.w((K, K), (ax_in, None), dtype=dtype)
    if bias:
        out["b"] = S.zeros((N,), (ax_out,), dtype=dtype)
    return out


# ---------------------------------------------------------------------------
# Offline quantization of a trained fp weight -> param arrays
# ---------------------------------------------------------------------------


def _certify_amplifier(scales, alpha: int, qspec: QuantSpec):
    """Static INT32-overflow certificate for this layer's amplifier.

    Returns the Certificate (also appended to repro.analysis.certify's
    log), or None when the scales are traced (inside jit/vmap the
    concrete values don't exist; certification then happens at the
    recipe/registry level instead).
    """
    import numpy as np

    try:
        s = np.asarray(scales)
    except Exception:  # traced values (TracerArrayConversionError etc.)
        return None
    from repro.analysis import certify

    return certify.resolve_amplifier(
        s, alpha=int(alpha), group_size=qspec.group_size,
        w_bits=qspec.w_bits, a_bits=qspec.a_bits)


def finish_quant(
    codes: jax.Array,   # int8 (K, N) quantized codes
    scales: jax.Array,  # f32 (G, N) (G=1 for coarse)
    qspec: QuantSpec,
    *,
    bias: jax.Array | None = None,
    pre_scale: jax.Array | None = None,
    rot: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """Shared finishing step for every algorithm: pack int4, integerize the
    scales (the paper's free lunch), assemble the param dict.

    Quantization-health telemetry lands here: one ``quantized_layers_total``
    tick per finished layer, and ``alpha_cap_events_total`` whenever the
    overflow certificate forces the amplifier below the requested value.
    ``alpha_cap_events_total`` is created unconditionally so an explicit
    zero appears in snapshots even on runs that never cap.
    """
    reg = obs.current_registry()
    caps = reg.counter(
        "alpha_cap_events_total",
        "layers whose amplifier was capped below request by the "
        "INT32-overflow certificate")
    caps.inc(0)  # materialize the series: snapshots show an explicit 0
    qvalue = packing.pack_int4(codes) if qspec.w_bits == 4 else codes
    out: dict[str, jax.Array] = {"qvalue": qvalue}
    if (qspec.scale_mode == "integer" and not qspec.weight_only
            and qspec.fine_grained):
        # Integer Scale applies to fine-grained group scales (paper §4);
        # coarse specs keep the single float scale (nothing to amortize).
        qw = QWeight(codes, scales, qspec.w_bits, qspec.group_size)
        isw = integerize(qw, qspec.amplifier)
        cert = _certify_amplifier(scales, isw.alpha, qspec)
        if cert is not None and cert.resolved_alpha != isw.alpha:
            # statically unsafe amplifier: rebuild at the certified cap
            caps.inc()
            isw = integerize(qw, cert.resolved_alpha)
        scheme = f"w{qspec.w_bits}a{qspec.a_bits}-is"
        out["scale"] = isw.int_scale
        out["alpha"] = jnp.float32(isw.alpha)
    else:
        scheme = (f"w{qspec.w_bits}a16" if qspec.weight_only
                  else f"w{qspec.w_bits}a{qspec.a_bits}-fs")
        out["scale"] = scales
    reg.counter("quantized_layers_total",
                "linear layers finished by finish_quant",
                ("scheme",)).inc(scheme=scheme)
    if bias is not None:
        out["b"] = bias
    if pre_scale is not None:
        out["pre_scale"] = jnp.asarray(pre_scale, jnp.float32)
    if rot is not None:
        out["rot"] = rot
    return out


def quantize_linear(
    w: jax.Array,
    qspec: QuantSpec,
    *,
    bias: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """RTN path (algorithms/ provide GPTQ/AWQ/... on top of finish_quant)."""
    K, N = w.shape
    qw = quantize_weight(w, qspec.w_bits, qspec.group_size, qspec.clip_ratio)
    scales = qw.scale if qspec.fine_grained else qw.scale[None, :]
    return finish_quant(qw.qvalue, scales, qspec, bias=bias)


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _unpack(params: dict, qspec: QuantSpec, K: int) -> jax.Array:
    if qspec.w_bits == 4:
        return packing.unpack_int4(params["qvalue"])
    return params["qvalue"]


def linear_apply(
    params: dict[str, jax.Array],
    x: jax.Array,
    qspec: QuantSpec | None,
    *,
    mode: KernelMode | None = None,
) -> jax.Array:
    """y = x @ W (+ b), honoring the quantization spec.

    x: (..., K) activation (bf16/f32). Returns same float dtype as x.
    """
    mode = mode or current_kernel_mode()
    if qspec is None:
        y = x @ params["w"].astype(x.dtype)
        if "b" in params:
            y = y + params["b"].astype(y.dtype)
        return y

    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    out_dtype = x.dtype

    if "pre_scale" in params:  # AWQ/SmoothQuant activation compensation
        x2 = x2 / params["pre_scale"].astype(x2.dtype)
    if "rot" in params:  # QuaRot-style online rotation
        x2 = x2 @ params["rot"].astype(x2.dtype)

    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        # the param dict carries the stored per-layer ``alpha`` — qgemm
        # forwards it, so heuristic-amplifier layers use their certified
        # value rather than any static qspec fallback.
        y2 = kops.qgemm(
            x2, params, qspec,
            block=kops.BlockConfig(interpret=(mode == "pallas_interpret")),
        )
    else:
        y2 = _reference_qgemm(x2, params, qspec, K)

    y = y2.reshape(*lead, -1).astype(out_dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def _reference_qgemm(x2, params, qspec: QuantSpec, K: int) -> jax.Array:
    """Pure-jnp semantics of every supported scheme (also the dry-run path —
    int8 dot_generals appear in HLO so the roofline sees integer compute)."""
    wq = _unpack(params, qspec, K)  # int8 (K, N)
    N = wq.shape[1]
    gs = qspec.group_size if qspec.group_size > 0 else K
    G = K // gs
    scale = params["scale"]

    if qspec.weight_only:
        # W4A16 Marlin-analog: dequant to activation dtype, fp GEMM.
        w = wq.reshape(G, gs, N).astype(jnp.float32) * scale[:, None, :]
        return x2 @ w.reshape(K, N).astype(x2.dtype)

    xq, sa = quantize_activation(x2, qspec.a_bits)  # int8, (M,1) f32
    x3 = xq.reshape(-1, G, gs)
    w3 = wq.reshape(G, gs, N)
    part = jax.lax.dot_general(
        x3, w3,
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (G, M, N)
    if qspec.scale_mode == "integer" and qspec.fine_grained:
        acc = jnp.sum(part * scale[:, None, :], axis=0)  # int32
        return acc.astype(jnp.float32) * (sa / params["alpha"])
    acc = jnp.sum(part.astype(jnp.float32) * scale[:, None, :], axis=0)
    return acc * sa


def grouped_linear_apply(
    params: dict[str, jax.Array],
    x: jax.Array,
    qspec: QuantSpec | None,
    *,
    row_counts: jax.Array | None = None,
    mode: KernelMode | None = None,
) -> jax.Array:
    """Batched-expert linear: x (E, C, K) -> (E, C, N), params stacked with
    a leading expert dim (the MoE dispatch-buffer path).

    Under "pallas"/"pallas_interpret" every expert runs in ONE grouped
    ragged Pallas kernel (``repro.kernels.moe_gemm``) with activation
    quantization fused into its first k-group pass — per-expert ``alpha``
    values from heuristic amplifiers are forwarded and folded into the
    activation scales. ``row_counts`` (int32 ``(E,)``, rows past it must be
    zero-filled) lets the kernel skip capacity-padding m-tiles; the
    reference branch ignores it (zero rows already produce zero outputs
    there), so both branches keep identical semantics. Activation
    compensation (``pre_scale``), rotation (``rot``) and bias are applied
    once here so both branches share the exact same semantics.
    """
    mode = mode or current_kernel_mode()
    if qspec is None:
        y = jnp.einsum("eck,ekn->ecn", x, params["w"].astype(x.dtype))
        if "b" in params:
            y = y + params["b"][:, None, :].astype(y.dtype)
        return y

    out_dtype = x.dtype
    x2 = x
    if "pre_scale" in params:  # (E, K) per-expert compensation
        x2 = x2 / params["pre_scale"][:, None, :].astype(x2.dtype)
    if "rot" in params:  # (E, K, K) per-expert rotation
        x2 = jnp.einsum("eck,ekj->ecj", x2, params["rot"].astype(x2.dtype))

    core = {k: v for k, v in params.items()
            if k in ("qvalue", "scale", "alpha")}
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops

        y = kops.qgemm_grouped(
            x2, core, qspec, row_counts=row_counts,
            block=kops.BlockConfig(interpret=(mode == "pallas_interpret")))
    else:
        K = x.shape[-1]
        y = jax.vmap(
            lambda p, xe: _reference_qgemm(xe, p, qspec, K))(core, x2)

    y = y.astype(out_dtype)
    if "b" in params:
        y = y + params["b"][:, None, :].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Whole-tree quantization: fp params -> quantized params per recipe
# ---------------------------------------------------------------------------


def quantize_tree(
    fp_params: Any,
    fp_specs: Any,
    recipe,
    *,
    adjusted: dict[str, jax.Array] | None = None,
) -> Any:
    """Walk a param tree; each dict node shaped like a linear ({"w": (K,N)})
    whose path matches the recipe is replaced by quantized arrays.

    ``adjusted``: optional path->weight overrides produced by calibration
    algorithms (GPTQ/AWQ/...) — quantization then uses the adjusted weight.
    """

    def walk(node, path):
        if isinstance(node, dict) and "w" in node and not isinstance(node["w"], dict):
            w = node["w"]
            if hasattr(w, "ndim") and w.ndim == 2:
                qspec = recipe.spec_for(path)
                if qspec is not None:
                    src = adjusted.get(path, w) if adjusted else w
                    return quantize_linear(
                        jnp.asarray(src, jnp.float32), qspec,
                        bias=node.get("b"),
                    )
            return node
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return node

    return walk(fp_params, "")
