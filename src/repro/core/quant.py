"""Quantization primitives (paper Appendix A).

Symmetric / asymmetric uniform quantization at per-tensor, per-token,
per-channel and group-wise (fine-grained) granularity, for both weights and
activations. Everything is pure jnp and jit-able; these are the building
blocks used by core.algorithms (GPTQ/AWQ/...), core.qlinear and the kernels'
reference oracles.

Conventions
-----------
* Weights are ``(K, N)`` = (in_features, out_features); quantization axes:
  - per-channel: one scale per output channel N  -> scales ``(N,)``
  - group-wise : K split into groups of ``group_size`` -> scales ``(K/g, N)``
* Activations are ``(..., K)``; per-token quantization gives one scale per
  row -> scales ``(..., 1)``.
* Symmetric int range for b bits: ``[-(2^{b-1}-1), 2^{b-1}-1]`` (e.g. int8:
  [-127,127], int4: [-7,7]) — matches the paper (Eq. 3-4).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

ScaleMode = Literal["float", "integer"]


def qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def qmin(bits: int, sym: bool = True) -> int:
    return -(2 ** (bits - 1) - 1) if sym else 0


# ---------------------------------------------------------------------------
# Scalar scale computation (Eq. 3 / Eq. 5)
# ---------------------------------------------------------------------------


def symmetric_scale(x: jax.Array, axis, bits: int, keepdims=True, eps=1e-8,
                    where: str | None = None):
    """``where`` labels amax-floor telemetry (e.g. "weight"/"activation");
    when set and the input is host-concrete, rows whose absmax fell below
    ``eps`` are counted in ``amax_floor_hits_total{where}`` (an all-zero
    channel/token quantizes to garbage scale 1/qmax — worth surfacing)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    if where is not None:
        _record_amax_floor(amax, eps, where)
    return jnp.maximum(amax, eps) / qmax(bits)


def _record_amax_floor(amax, eps: float, where: str) -> None:
    try:
        a = np.asarray(amax)
    except Exception:  # traced (jit/vmap): skip, per the repro.obs rule
        return
    obs.current_registry().counter(
        "amax_floor_hits_total",
        "quantization scales hitting the eps amax floor", ("where",),
    ).inc(int((a < eps).sum()), where=where)


def asymmetric_scale_zp(x: jax.Array, axis, bits: int, keepdims=True, eps=1e-8):
    xmax = jnp.max(x, axis=axis, keepdims=keepdims)
    xmin = jnp.min(x, axis=axis, keepdims=keepdims)
    scale = jnp.maximum(xmax - xmin, eps) / (2**bits - 1)
    zp = jnp.floor(-xmin / scale + 0.5)
    return scale, zp


def quantize(x, scale, bits: int, sym: bool = True, zp=None):
    """Round-to-nearest quantize with clamping (Eq. 4 / Eq. 6)."""
    if sym:
        q = jnp.clip(jnp.round(x / scale), qmin(bits), qmax(bits))
    else:
        q = jnp.clip(jnp.round(x / scale) + zp, 0, 2**bits - 1)
    return q


def dequantize(q, scale, sym: bool = True, zp=None):
    if sym:
        return q * scale
    return (q - zp) * scale


# ---------------------------------------------------------------------------
# Weight quantization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QWeight:
    """Quantized weight bundle (always symmetric per the paper's main setup).

    ``qvalue`` is int8 storage regardless of logical bit-width (int4 values
    occupy int8 here; the kernels' packer nibble-packs separately).
    ``scale``: per-channel -> (N,), group-wise -> (K/g, N). float32.
    """

    qvalue: jax.Array  # int8, (K, N)
    scale: jax.Array  # f32, (N,) or (K/g, N)
    bits: int
    group_size: int  # -1 => per-channel (coarse)

    @property
    def fine_grained(self) -> bool:
        return self.group_size > 0

    def dequant(self) -> jax.Array:
        if not self.fine_grained:
            return self.qvalue.astype(jnp.float32) * self.scale[None, :]
        K, N = self.qvalue.shape
        g = self.group_size
        wq = self.qvalue.reshape(K // g, g, N).astype(jnp.float32)
        return (wq * self.scale[:, None, :]).reshape(K, N)


def quantize_weight(
    w: jax.Array, bits: int, group_size: int = -1, clip_ratio: float = 1.0
) -> QWeight:
    """Symmetric RTN weight quantization, coarse (per-channel) or fine (group).

    ``clip_ratio`` < 1 shrinks the absmax before computing the scale
    (used by AWQ-style clipping search).
    """
    if w.ndim != 2:
        raise ValueError(f"weights must be (K, N), got {w.shape}")
    K, N = w.shape
    w = w.astype(jnp.float32)
    if group_size <= 0:
        scale = symmetric_scale(w * clip_ratio, axis=0, bits=bits,
                                keepdims=False, where="weight")
        q = quantize(w, scale[None, :], bits)
        return QWeight(q.astype(jnp.int8), scale, bits, -1)
    if K % group_size != 0:
        raise ValueError(f"K={K} not divisible by group_size={group_size}")
    wg = w.reshape(K // group_size, group_size, N)
    scale = symmetric_scale(wg * clip_ratio, axis=1, bits=bits, keepdims=False,
                            where="weight")
    q = quantize(wg, scale[:, None, :], bits)
    return QWeight(q.reshape(K, N).astype(jnp.int8), scale, bits, group_size)


# ---------------------------------------------------------------------------
# Activation quantization (per-token, symmetric — paper default)
# ---------------------------------------------------------------------------


def quantize_activation(x: jax.Array, bits: int = 8):
    """Per-token symmetric quantization of the last axis.

    Returns (q int8, scale f32 broadcastable over last axis).
    """
    scale = symmetric_scale(x.astype(jnp.float32), axis=-1, bits=bits,
                            where="activation")
    q = quantize(x.astype(jnp.float32), scale, bits).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Fine-grained GEMM reference semantics (Eq. 1) — float scale
# ---------------------------------------------------------------------------


def fg_gemm_float_scale(
    xq: jax.Array,  # int8 (..., K)
    sa: jax.Array,  # f32  (..., 1) per-token
    qw: QWeight,
) -> jax.Array:
    """Eq. 1: per-group integer matmul, each partial converted to f32 and
    scaled by the group's float scale, then accumulated in f32."""
    K, N = qw.qvalue.shape
    g = qw.group_size if qw.fine_grained else K
    G = K // g
    x3 = xq.reshape(*xq.shape[:-1], G, g)
    w3 = qw.qvalue.reshape(G, g, N)
    # (..., G, g) x (G, g, N) -> (..., G, N) int32 partials
    part = jax.lax.dot_general(
        x3, w3,
        dimension_numbers=(((x3.ndim - 1,), (1,)), ((x3.ndim - 2,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (G, ..., N) — batch dims come first
    part = jnp.moveaxis(part, 0, -2)  # (..., G, N)
    scale = qw.scale if qw.fine_grained else qw.scale[None, :] * jnp.ones((1, 1))
    if not qw.fine_grained:
        scale = qw.scale.reshape(1, N)
    acc = jnp.sum(part.astype(jnp.float32) * scale, axis=-2)  # (..., N)
    return acc * sa


# ---------------------------------------------------------------------------
# Utility: quantization error metrics
# ---------------------------------------------------------------------------


def weight_mse(w: jax.Array, qw: QWeight) -> jax.Array:
    return jnp.mean((w.astype(jnp.float32) - qw.dequant()) ** 2)


def output_mse(w, qw, x) -> jax.Array:
    ref = x.astype(jnp.float32) @ w.astype(jnp.float32)
    xq, sa = quantize_activation(x)
    out = fg_gemm_float_scale(xq, sa, qw)
    return jnp.mean((ref - out) ** 2)
