"""Post-training quantization orchestrator.

``post_training_quantize`` turns a trained fp param tree into a quantized
one per a :class:`~repro.core.recipe.QuantRecipe`:

  1. run calibration batches EAGERLY with ``cfg.scan_layers=False`` while
     ``models.common`` capture hooks record each linear's input
     activations per (path, layer-call-order);
  2. per linear, run the spec's algorithm (rtn/gptq/awq/smoothquant/
     omniquant, optionally QuaRot rotation) -> codes + float scales
     (+ pre_scale / rot);
  3. finish with the Integer Scale conversion (or keep float scales) via
     ``qlinear.finish_quant`` — the paper's plug-and-play step.

Which tensors quantize is decided by walking the *quantized spec tree*
(``api.param_specs(cfg, recipe)``) in parallel with the fp params — only
nodes the model itself declared as quantized linears convert, so heads,
embeddings, conv filters and gate vectors stay fp exactly as the specs say.
Stacked (scanned) weights quantize layer-by-layer (captured activations are
indexed by call order), then re-stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import common as MC
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from . import qlinear
from .algorithms.awq import awq_quantize
from .algorithms.gptq import gptq_quantize
from .algorithms.omniquant import omniquant_quantize
from .algorithms.quarot import quarot_quantize
from .algorithms.smoothquant import smoothquant_quantize
from .recipe import QuantRecipe, QuantSpec


def collect_calibration(api: ModelApi, cfg: ModelConfig, fp_params: Any,
                        batches: list[dict]) -> dict[str, list[np.ndarray]]:
    """Run batches eagerly (unrolled layers) and capture linear inputs."""
    cfg_unrolled = dataclasses.replace(cfg, scan_layers=False)
    MC.start_capture()
    try:
        for b in batches:
            api.apply(fp_params, cfg_unrolled, jnp.asarray(b["tokens"]),
                      mode="train",
                      memory=(jnp.asarray(b["image_embeds"])
                              if "image_embeds" in b else
                              jnp.asarray(b["frames"])
                              if "frames" in b else None))
    finally:
        captured = MC.end_capture()
    return captured


def _calib_for(captured: dict, path: str, layer: int | None,
               n_layers: int) -> np.ndarray:
    """Per-batch call order for a scanned path is [b0: l0..lL-1, b1: ...]."""
    recs = captured.get(path, [])
    if not recs:
        return np.zeros((0, 0), np.float32)
    if layer is None or n_layers <= 1:
        return np.concatenate(recs, axis=0)
    per_batch = len(recs) // n_layers
    if per_batch == 0:
        return np.concatenate(recs, axis=0)
    picks = [recs[b * n_layers + layer] for b in range(per_batch)]
    return np.concatenate(picks, axis=0)


def quantize_one(w: np.ndarray, x: np.ndarray, spec: QuantSpec,
                 bias=None, seed: int = 0) -> dict:
    """One linear: algorithm -> codes/scales(+extras) -> finish_quant."""
    w = np.asarray(w, np.float32)
    if spec.rotate:
        codes, scales, rot_np = quarot_quantize(
            w, spec.w_bits, spec.group_size, seed=seed)
        return qlinear.finish_quant(
            jnp.asarray(codes), jnp.asarray(scales), spec, bias=bias,
            rot=jnp.asarray(rot_np, jnp.bfloat16))
    if spec.algo in ("rtn", "odyssey") or x.size == 0:
        from .quant import quantize_weight

        gs = -1 if spec.algo == "odyssey" else spec.group_size
        eff = dataclasses.replace(spec, group_size=gs)
        qw = quantize_weight(jnp.asarray(w), spec.w_bits, gs,
                             spec.clip_ratio)
        scales = qw.scale if eff.fine_grained else qw.scale[None, :]
        return qlinear.finish_quant(qw.qvalue, scales, eff, bias=bias)
    if spec.algo == "gptq":
        codes, scales = gptq_quantize(w, x, spec.w_bits, spec.group_size)
        pre_scale = None
    elif spec.algo == "awq":
        codes, scales, pre_scale = awq_quantize(
            w, x, spec.w_bits, spec.group_size)
    elif spec.algo == "smoothquant":
        codes, scales, pre_scale = smoothquant_quantize(
            w, x, spec.w_bits, spec.group_size)
    elif spec.algo == "omniquant":
        codes, scales = omniquant_quantize(w, x, spec.w_bits,
                                           spec.group_size)
        pre_scale = None
    else:
        raise ValueError(spec.algo)
    return qlinear.finish_quant(
        jnp.asarray(codes), jnp.asarray(scales), spec,
        bias=bias, pre_scale=pre_scale)


def post_training_quantize(api: ModelApi, cfg: ModelConfig, fp_params: Any,
                           recipe: QuantRecipe,
                           calib_batches: list[dict] | None = None) -> Any:
    """fp params tree -> quantized params tree matching
    ``api.param_specs(cfg, recipe)``."""
    qspec_tree = api.param_specs(cfg, recipe)
    needs_calib = any(
        spec is not None and (spec.algo != "rtn" or spec.rotate)
        for _, spec in recipe.rules)
    captured: dict = {}
    if needs_calib and calib_batches:
        captured = collect_calibration(api, cfg, fp_params, calib_batches)

    from repro.analysis import certify

    def walk(fp_node, spec_node, path):
        if isinstance(spec_node, dict) and "qvalue" in spec_node:
            # model declared this node quantized
            spec = recipe.spec_for(path)
            assert spec is not None, path
            w = np.asarray(fp_node["w"], np.float32)
            bias = fp_node.get("b")
            with certify.context(path):
                return _quantize_node(w, bias, spec, path, captured)
        if isinstance(spec_node, dict):
            return {k: walk(fp_node[k], v, f"{path}/{k}" if path else k)
                    for k, v in spec_node.items()}
        return fp_node

    n_before = len(certify.log())
    reg = obs.current_registry()
    s = None
    with obs.span(reg, "ptq_run_seconds", event="ptq_run") as sp:
        out = walk(fp_params, qspec_tree, "")
        certs = certify.log()[n_before:]
        sp.fields["certificates"] = len(certs)
        if certs:
            s = certify.summary(certs)
            sp.fields.update(certified=s["certified"],
                             capped_alpha=s["capped-alpha"],
                             fallback=s["fallback"])
    reg.counter("ptq_runs_total", "post_training_quantize invocations").inc()
    if s is not None:
        print(f"[ptq] overflow certificates: {s['certified']} certified / "
              f"{s['capped-alpha']} capped-alpha / {s['fallback']} fallback"
              f" (worst accumulator {s['worst_frac']:.3f} of 2^31)")
        for c in certs:
            if c.verdict != "certified":
                print(f"[ptq]   {c}")
    return out


def _quantize_node(w, bias, spec, path, captured):
    """Quantize one declared-quantized node (2D / scanned 3D / >=4D)."""
    if w.ndim == 2:
        x = _calib_for(captured, path, None, 1)
        return quantize_one(w, x, spec, bias=bias)
    if w.ndim == 3:  # scanned layers OR experts: per-slice calib
        L = w.shape[0]
        outs = [quantize_one(
            w[i], _calib_for(captured, path, i, L), spec,
            bias=(bias[i] if bias is not None else None), seed=i)
            for i in range(L)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    # >=4D (scanned MoE: layers x experts x K x N): RTN+IS per slice
    lead = w.shape[:-2]
    flat = w.reshape(-1, *w.shape[-2:])
    bflat = (np.asarray(bias).reshape(-1, bias.shape[-1])
             if bias is not None else None)
    outs = [quantize_one(
        flat[i], np.zeros((0, 0), np.float32), spec,
        bias=(bflat[i] if bflat is not None else None), seed=i)
        for i in range(flat.shape[0])]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return jax.tree.map(
        lambda a: a.reshape(*lead, *a.shape[1:]), stacked)
