"""Omniquant-lite (Shao et al., arXiv:2308.13137): weight clipping search.

The full Omniquant learns clipping + smoothing by gradient descent; this
lite version grid-searches the clip ratio per layer against the calibrated
output MSE — the same "learnable weight clipping" degree of freedom,
optimized by direct search (adequate at this model scale; documented
deviation in DESIGN.md).
"""
from __future__ import annotations

import numpy as np

from repro.core.quant import qmax


def omniquant_quantize(
    w: np.ndarray,   # (K, N)
    x: np.ndarray,   # (n, K)
    bits: int,
    group_size: int,
    grid=(1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7),
) -> tuple[np.ndarray, np.ndarray]:
    K, N = w.shape
    gs = group_size if group_size > 0 else K
    G = K // gs
    qm = qmax(bits)
    x = x.astype(np.float32)
    ref = x @ w
    w3 = w.reshape(G, gs, N)
    best = (None, None, np.inf)
    for clip in grid:
        s = np.maximum(np.abs(w3).max(axis=1) * clip, 1e-8) / qm
        q = np.clip(np.round(w3 / s[:, None, :]), -qm, qm)
        deq = (q * s[:, None, :]).reshape(K, N)
        mse = float(((ref - x @ deq) ** 2).mean())
        if mse < best[2]:
            best = (q.reshape(K, N).astype(np.int8), s.astype(np.float32),
                    mse)
    return best[0], best[1]
