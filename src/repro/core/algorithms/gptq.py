"""GPTQ (Frantar et al., arXiv:2210.17323): approximate second-order PTQ.

Per layer: Hessian H = 2 X^T X from calibration activations; iterate over
input dims in order, quantize each weight row, and distribute the induced
error onto not-yet-quantized rows via the Cholesky factor of H^{-1}.
Group-wise scales are (re)computed at each group boundary from the
*current* (error-compensated) weights — the standard fine-grained GPTQ.

Numpy implementation (offline, layer-at-a-time; K <= few-thousand here).
"""
from __future__ import annotations

import numpy as np

from repro.core.quant import qmax


def gptq_quantize(
    w: np.ndarray,       # (K, N) f32 — rows are input features
    x: np.ndarray,       # (n, K) f32 calibration inputs
    bits: int,
    group_size: int,
    percdamp: float = 0.01,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (codes int8 (K, N), scales f32 (G, N))."""
    K, N = w.shape
    gs = group_size if group_size > 0 else K
    G = K // gs
    qm = qmax(bits)

    H = 2.0 * (x.T @ x).astype(np.float64)  # (K, K)
    # dead inputs: keep numerically sane
    dead = np.diag(H) == 0
    H[dead, dead] = 1.0
    w = w.astype(np.float64).copy()
    w[dead, :] = 0.0
    damp = percdamp * np.mean(np.diag(H))
    H[np.diag_indices(K)] += damp

    # Cholesky of H^{-1}, upper-triangular (GPTQ's preferred form)
    import scipy.linalg

    Hinv = scipy.linalg.cholesky(np.linalg.inv(H), lower=False)
    codes = np.zeros((K, N), np.int8)
    scales = np.zeros((G, N), np.float32)

    for g in range(G):
        i0, i1 = g * gs, (g + 1) * gs
        # group scale from current (compensated) weights
        s = np.maximum(np.abs(w[i0:i1]).max(axis=0), 1e-8) / qm  # (N,)
        scales[g] = s.astype(np.float32)
        for i in range(i0, i1):
            d = Hinv[i, i]
            q = np.clip(np.round(w[i] / s), -qm, qm)
            codes[i] = q.astype(np.int8)
            err = (w[i] - q * s) / d
            # compensate all remaining rows
            if i + 1 < K:
                w[i + 1:] -= np.outer(Hinv[i, i + 1:], err)
    return codes, scales
