"""AWQ (Lin et al., arXiv:2306.00978): activation-aware weight scaling.

Salient input channels (large mean |x|) get their weights scaled UP before
quantization (finer effective resolution) and the activations scaled DOWN
correspondingly at runtime (the ``pre_scale`` in qlinear). The exponent
alpha is grid-searched per layer to minimize the quantized output MSE —
exactly AWQ's search, with the scale realized online instead of folded
into the previous layer (equivalent math; see DESIGN.md).
"""
from __future__ import annotations

import numpy as np

from repro.core.quant import qmax


def _rtn(w: np.ndarray, bits: int, gs: int):
    K, N = w.shape
    G = K // gs
    qm = qmax(bits)
    w3 = w.reshape(G, gs, N)
    s = np.maximum(np.abs(w3).max(axis=1), 1e-8) / qm  # (G, N)
    q = np.clip(np.round(w3 / s[:, None, :]), -qm, qm)
    return q.reshape(K, N).astype(np.int8), s.astype(np.float32)


def awq_quantize(
    w: np.ndarray,   # (K, N)
    x: np.ndarray,   # (n, K)
    bits: int,
    group_size: int,
    grid: int = 8,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (codes, scales, pre_scale (K,))."""
    K, N = w.shape
    gs = group_size if group_size > 0 else K
    x = x.astype(np.float32)
    act_mag = np.maximum(np.abs(x).mean(axis=0), 1e-6)  # (K,)
    ref = x @ w
    best = (None, None, None, np.inf)
    for j in range(grid + 1):
        alpha = j / grid
        s = act_mag ** alpha
        s = s / (np.sqrt(s.max() * s.min()) + 1e-12)  # normalize (AWQ)
        s = np.maximum(s, 1e-4)
        codes, scales = _rtn(w * s[:, None], bits, gs)
        deq = codes.astype(np.float32).reshape(K // gs, gs, N) \
            * scales[:, None, :]
        out = (x / s[None, :]) @ deq.reshape(K, N)
        mse = float(((ref - out) ** 2).mean())
        if mse < best[3]:
            best = (codes, scales, s.astype(np.float32), mse)
    return best[0], best[1], best[2]
