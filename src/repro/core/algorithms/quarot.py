"""QuaRot-lite (Ashkboos et al., arXiv:2404.00456): rotation-based PTQ.

Computation-invariant orthogonal rotation: W' = Q^T W with x rotated
online (x' = x Q), so x'W' = xW exactly while the rotated weight (and
activation) distributions are incoherent — outliers are spread out, which
is what rescues W4A4 (paper Table 1's QuaRot rows). We use a seeded random
orthogonal Q (QR of a Gaussian) — the Hadamard of the original is a
special case; random orthogonal has the same incoherence property
(QuIP/QuaRot theory) without the power-of-two size restriction.
"""
from __future__ import annotations

import numpy as np

from .awq import _rtn


def random_orthogonal(K: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((K, K))
    q, r = np.linalg.qr(a)
    # fix signs for determinism
    q = q * np.sign(np.diag(r))[None, :]
    return q.astype(np.float32)


def quarot_quantize(
    w: np.ndarray,   # (K, N)
    bits: int,
    group_size: int,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (codes, scales, rot (K,K)) for W' = rot.T @ W."""
    K, N = w.shape
    gs = group_size if group_size > 0 else K
    rot = random_orthogonal(K, seed)
    codes, scales = _rtn(rot.T @ w, bits, gs)
    return codes, scales, rot
