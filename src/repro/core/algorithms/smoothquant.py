"""SmoothQuant (Xiao et al., arXiv:2211.10438): outlier migration.

s_j = max|x_j|^alpha / max|w_j|^(1-alpha) — activations divided by s,
weights multiplied by s (realized as qlinear ``pre_scale``, identical
math to folding into the previous layer). alpha=0.5 default.
"""
from __future__ import annotations

import numpy as np

from .awq import _rtn


def smoothquant_quantize(
    w: np.ndarray,   # (K, N)
    x: np.ndarray,   # (n, K)
    bits: int,
    group_size: int,
    alpha: float = 0.5,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    K, N = w.shape
    gs = group_size if group_size > 0 else K
    x_max = np.maximum(np.abs(x).max(axis=0), 1e-5)        # (K,)
    w_max = np.maximum(np.abs(w).max(axis=1), 1e-5)        # (K,)
    s = (x_max ** alpha) / (w_max ** (1 - alpha))
    s = np.maximum(s, 1e-4).astype(np.float32)
    codes, scales = _rtn(w * s[:, None], bits, gs)
    return codes, scales, s
