"""Device-time attribution and opt-in profiler capture windows.

The host wall-clock spans (``repro.obs.tracing``) time whole tick phases
— jitted compute, dispatch overhead, sampling, cache splicing, python
bookkeeping, all mixed. This module separates the device component:

* :func:`device_timer` wraps a (typically jitted) callable so every call
  is ``jax.block_until_ready``-bracketed and observed into a
  ``*_device_seconds`` histogram on the *current* registry. The first
  ``warmup`` calls — which pay trace+compile — are excluded from the
  histogram (they land in a ``*_device_warmup_total`` counter instead),
  so the series reflects steady-state device time. Subtracting it from
  the enclosing host span gives host overhead per phase.
* :func:`trace_window` is the ``jax.profiler.trace`` capture window
  behind ``launch/serve.py --profile-dir`` / ``benchmarks/run.py
  --profile-dir``: a no-op when the dir is falsy, otherwise the XLA
  profiler writes ``plugins/profile/<ts>/*.xplane.pb`` under the dir
  (open in TensorBoard's profile plugin or convert for Perfetto).

The "no metrics inside jitted bodies" rule holds: both helpers sit on
the host side of the jit boundary — the wrapped callable's jit cache is
untouched (arguments pass through verbatim), so decode still traces
exactly once with a device timer attached. ``jax`` is imported lazily so
``repro.obs`` itself stays importable without it.
"""
from __future__ import annotations

import contextlib

from .metrics import DEFAULT_LATENCY_BUCKETS, current_registry


def device_timer(fn, metric: str, *, warmup: int = 1, help: str = "",
                 **labels):
    """Wrap ``fn`` with block_until_ready-bracketed device timing.

    ``metric`` must end in ``_device_seconds`` (the naming contract that
    pairs it with the host ``*_seconds`` span histogram). The registry is
    resolved per call via :func:`current_registry`, and its clock is used
    — a fake clock drives deterministic tests end-to-end.
    """
    if not metric.endswith("_device_seconds"):
        raise ValueError(
            f"device_timer metric {metric!r} must end '_device_seconds'")
    warm_metric = metric[: -len("_device_seconds")] + "_device_warmup_total"
    state = {"calls": 0}

    def timed(*args, **kwargs):
        import jax

        reg = current_registry()
        t0 = reg.now()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        dt = reg.now() - t0
        state["calls"] += 1
        if state["calls"] > warmup:
            reg.histogram(metric, help, tuple(sorted(labels)),
                          buckets=DEFAULT_LATENCY_BUCKETS,
                          ).observe(dt, **labels)
        else:
            reg.counter(warm_metric,
                        "device_timer calls excluded as warmup/compile",
                        tuple(sorted(labels))).inc(**labels)
        return out

    timed.calls = lambda: state["calls"]
    timed.__wrapped__ = fn
    return timed


@contextlib.contextmanager
def trace_window(log_dir: str | None):
    """Opt-in ``jax.profiler.trace`` capture: no-op when ``log_dir`` is
    falsy, else profile the enclosed block into ``log_dir``."""
    if not log_dir:
        yield None
        return
    import jax

    with jax.profiler.trace(log_dir):
        yield log_dir
