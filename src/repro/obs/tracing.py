"""Span timing helpers for host-side tick tracing.

A :class:`Span` measures wall-clock around a host-side block (an engine
tick phase, a benchmark section), observes the duration into a labeled
histogram, and optionally emits one event into the registry's JSONL log.
Time comes from the registry's monotonic clock (injectable for tests),
and the emitted event is stamped with the span's START time (``ts``) plus
its duration (``seconds``) — the pair :mod:`repro.obs.timeline` turns
into Perfetto slices. Spans are HOST constructs — never open one inside a
jitted body (see the package docstring's "no metrics inside jitted
bodies" rule).
"""
from __future__ import annotations

from .metrics import DEFAULT_LATENCY_BUCKETS, Registry


class Span:
    """Context manager: time a block, observe it, optionally emit an event.

    ``span.fields`` is a mutable dict the caller can annotate while the
    span is open; the fields land in the emitted event (when ``event`` is
    set). ``span.seconds`` holds the duration after exit.
    """

    def __init__(self, registry: Registry, metric: str, *,
                 event: str | None = None,
                 buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS,
                 help: str = "", **labels):
        self.registry = registry
        self.metric = metric
        self.event = event
        self.buckets = buckets
        self.help = help
        self.labels = labels
        self.fields: dict = {}
        self.seconds = 0.0
        self._t0 = 0.0

    def __enter__(self) -> "Span":
        self._t0 = self.registry.now()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = self.registry.now() - self._t0
        hist = self.registry.histogram(
            self.metric, self.help, tuple(sorted(self.labels)),
            buckets=self.buckets)
        hist.observe(self.seconds, **self.labels)
        if self.event is not None:
            self.registry.emit({"ev": self.event,
                                "ts": round(self._t0, 6), **self.labels,
                                "seconds": round(self.seconds, 6),
                                **self.fields})


def span(registry: Registry, metric: str, **kw) -> Span:
    """Shorthand: ``with obs.span(reg, "engine_phase_seconds",
    phase="decode") as sp: ...``."""
    return Span(registry, metric, **kw)
