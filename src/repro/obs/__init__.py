"""repro.obs — serving + kernel telemetry (metrics registry, tick tracing).

A dependency-free (stdlib-only) observability layer shared by the serving
engine, the kernel dispatch wrappers, and the quantization pipeline:

* :class:`Registry` — process-local counters / gauges / histograms with
  labeled series, deterministic fixed-bucket histograms, a JSONL event
  log, and a Prometheus-text snapshot. Instrumentation resolves the
  active registry via :func:`current_registry` (process default, scoped
  override via :func:`use_registry`).
* :class:`Span` / :func:`span` — host-side wall-clock tick tracing that
  lands in a histogram + the event log (stamped with registry-clock
  start times, so spans double as timeline slices).
* :mod:`repro.obs.timeline` — Perfetto/chrome://tracing export of the
  event log: engine-phase lane, per-request-slot lifecycle lanes
  (queued -> prefill -> decode ticks -> retire, TTFT/TPOT markers), and
  m-tile / qgemm counter tracks. ``launch/serve.py --trace-out t.json``
  writes one; open it at https://ui.perfetto.dev ("Open trace file").
* :mod:`repro.obs.profile` — device-time attribution:
  :func:`~repro.obs.profile.device_timer` wraps jitted callables with
  block_until_ready-bracketed, warmup-aware timing into
  ``*_device_seconds`` histograms (so host overhead = host span minus
  device time, per phase), and
  :func:`~repro.obs.profile.trace_window` is the opt-in
  ``jax.profiler.trace`` capture behind ``--profile-dir``.
* :meth:`Histogram.quantile` / ``snapshot()["histograms"][...]
  ["quantiles"]`` — p50/p95/p99 derived from the fixed cumulative
  buckets (Prometheus ``histogram_quantile`` interpolation; overflow
  clamps to the last finite edge), surfaced in the serve telemetry
  cell, ``launch/dryrun.py``, and benchmark JSON.
* ``benchmarks/regression.py`` (consumer, not part of this package)
  turns two ``benchmarks.run --json`` documents into an enforced perf
  contract — see its docstring for the baseline-refresh procedure.

What is instrumented where
--------------------------
* ``serving/engine.py``: per-tick admit/prefill/decode/retire spans
  (``engine_phase_seconds``), tick/token/request counters, slot-occupancy
  and queue-depth gauges, per-request TTFT/TPOT histograms, jit retrace
  events (``engine_traces_total``), and per-tick executed-vs-total MoE
  m-tile counters (``engine_moe_m_tiles_total``) fed by the routing sink
  in ``models/moe.py``. Fault tolerance (PR 10):
  ``engine_request_outcomes_total{outcome}`` counts every request's
  terminal outcome (``ok|timeout|cancelled|rejected|nan|error``; all
  series zero-seeded, so the conservation law — outcomes sum to
  ``engine_requests_total{event="submitted"}`` once drained — is
  checkable from any snapshot), ``engine_fallback_events_total{reason}``
  counts circuit-breaker kernel-route fallbacks,
  ``engine_kernel_failures_total{phase}`` counts exceptions escaping the
  jitted paths, and ``engine_slow_ticks_total`` counts watchdog
  stragglers. ``repro.serving.chaos`` injects all of the above
  deterministically.
* ``kernels/ops.py``: ``qgemm_calls_total{scheme,kind,shape,block}`` per
  wrapper call, plus host-side ragged executed/total m-tile accounting
  (``qgemm_ragged_m_tiles_total``) whenever ``row_counts`` is concrete.
* ``core/qlinear.py`` / ``core/ptq.py`` / ``core/integer_scale.py`` /
  ``analysis/certify.py``: quantization health — ``alpha_cap_events_total``,
  ``qcert_verdicts_total{verdict}``, ``amax_floor_hits_total{where}``,
  ``int_scale_floor_hits_total``, ``quantized_layers_total{scheme}``.
* Surfacing: ``launch/serve.py --metrics-out`` (JSONL trace + final
  snapshot line), ``launch/dryrun.py`` telemetry cell, and benchmark JSON
  documents (``benchmarks/run.py`` / ``benchmarks/serving_moe.py`` attach
  a registry snapshot + host provenance).

THE RULE: no metrics inside jitted bodies
-----------------------------------------
Never read or write a metric from code that executes inside a traced /
jitted computation. A python-side increment inside a traced function runs
at TRACE time (once per compilation, not once per step) and anything
fancier would either retrace or insert host syncs into the hot path.
Instrument at these boundaries only:

* host code around a jitted call (engine tick phases, wrapper entry
  points — note wrapper counts are *trace-time* counts under jit, which
  is exactly what makes them a retrace detector);
* ``jax.debug.callback`` hooks staged at trace boundaries (the MoE
  routing sink) whose callbacks run host-side at execution time;
* offline paths that are eager by construction (PTQ, certification).

Data-dependent values (e.g. ragged ``row_counts``) may only be recorded
when they are concrete — guard with a ``np.asarray`` try/except and skip
silently when traced.

How to add a new counter
------------------------
1. Pick the layer's boundary per the rule above. 2. Create lazily at the
use site — ``obs.current_registry().counter("my_total", "help",
("label",)).inc(label="x")``; get-or-create is idempotent, so no central
declaration list exists. 3. Create the metric unconditionally and ``inc``
conditionally when dashboards must see an explicit zero (e.g.
``alpha_cap_events_total``). 4. Name per Prometheus convention:
``*_total`` counters, ``*_seconds`` histograms, unit-suffixed gauges.
"""
from . import profile, timeline
from .metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge, Histogram,
                      Registry, current_registry, default_registry,
                      use_registry)
from .profile import device_timer, trace_window
from .timeline import build_trace, write_trace
from .tracing import Span, span

__all__ = [
    "DEFAULT_LATENCY_BUCKETS", "Counter", "Gauge", "Histogram", "Registry",
    "Span", "build_trace", "current_registry", "default_registry",
    "device_timer", "profile", "span", "timeline", "trace_window",
    "use_registry", "write_trace",
]
