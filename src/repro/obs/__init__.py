"""repro.obs — serving + kernel telemetry (metrics registry, tick tracing).

A dependency-free (stdlib-only) observability layer shared by the serving
engine, the kernel dispatch wrappers, and the quantization pipeline:

* :class:`Registry` — process-local counters / gauges / histograms with
  labeled series, deterministic fixed-bucket histograms, a JSONL event
  log, and a Prometheus-text snapshot. Instrumentation resolves the
  active registry via :func:`current_registry` (process default, scoped
  override via :func:`use_registry`).
* :class:`Span` / :func:`span` — host-side wall-clock tick tracing that
  lands in a histogram + the event log.

What is instrumented where
--------------------------
* ``serving/engine.py``: per-tick admit/prefill/decode/retire spans
  (``engine_phase_seconds``), tick/token/request counters, slot-occupancy
  and queue-depth gauges, per-request TTFT/TPOT histograms, jit retrace
  events (``engine_traces_total``), and per-tick executed-vs-total MoE
  m-tile counters (``engine_moe_m_tiles_total``) fed by the routing sink
  in ``models/moe.py``.
* ``kernels/ops.py``: ``qgemm_calls_total{scheme,kind,shape,block}`` per
  wrapper call, plus host-side ragged executed/total m-tile accounting
  (``qgemm_ragged_m_tiles_total``) whenever ``row_counts`` is concrete.
* ``core/qlinear.py`` / ``core/ptq.py`` / ``core/integer_scale.py`` /
  ``analysis/certify.py``: quantization health — ``alpha_cap_events_total``,
  ``qcert_verdicts_total{verdict}``, ``amax_floor_hits_total{where}``,
  ``int_scale_floor_hits_total``, ``quantized_layers_total{scheme}``.
* Surfacing: ``launch/serve.py --metrics-out`` (JSONL trace + final
  snapshot line), ``launch/dryrun.py`` telemetry cell, and benchmark JSON
  documents (``benchmarks/run.py`` / ``benchmarks/serving_moe.py`` attach
  a registry snapshot + host provenance).

THE RULE: no metrics inside jitted bodies
-----------------------------------------
Never read or write a metric from code that executes inside a traced /
jitted computation. A python-side increment inside a traced function runs
at TRACE time (once per compilation, not once per step) and anything
fancier would either retrace or insert host syncs into the hot path.
Instrument at these boundaries only:

* host code around a jitted call (engine tick phases, wrapper entry
  points — note wrapper counts are *trace-time* counts under jit, which
  is exactly what makes them a retrace detector);
* ``jax.debug.callback`` hooks staged at trace boundaries (the MoE
  routing sink) whose callbacks run host-side at execution time;
* offline paths that are eager by construction (PTQ, certification).

Data-dependent values (e.g. ragged ``row_counts``) may only be recorded
when they are concrete — guard with a ``np.asarray`` try/except and skip
silently when traced.

How to add a new counter
------------------------
1. Pick the layer's boundary per the rule above. 2. Create lazily at the
use site — ``obs.current_registry().counter("my_total", "help",
("label",)).inc(label="x")``; get-or-create is idempotent, so no central
declaration list exists. 3. Create the metric unconditionally and ``inc``
conditionally when dashboards must see an explicit zero (e.g.
``alpha_cap_events_total``). 4. Name per Prometheus convention:
``*_total`` counters, ``*_seconds`` histograms, unit-suffixed gauges.
"""
from .metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge, Histogram,
                      Registry, current_registry, default_registry,
                      use_registry)
from .tracing import Span, span

__all__ = [
    "DEFAULT_LATENCY_BUCKETS", "Counter", "Gauge", "Histogram", "Registry",
    "Span", "current_registry", "default_registry", "span", "use_registry",
]
