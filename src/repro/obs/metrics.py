"""Process-local metrics registry: counters, gauges, histograms, events.

Dependency-free (stdlib only — no jax import, so ``repro.core`` /
``repro.kernels`` can instrument without import cycles) and deterministic:
histogram bucket edges are fixed at metric creation, snapshot/Prometheus
output is sorted by metric name then label key, and label series are keyed
by the declared ``labelnames`` order. Values are plain python floats.

The registry is resolved dynamically via :func:`current_registry` — a
default process-global instance with a ``use_registry`` override stack so
tests and benchmarks isolate their series without threading a handle
through every layer.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time

#: Fixed wall-clock latency bucket edges (seconds). Chosen to straddle both
#: interpret-mode CPU ticks (tens of ms .. s) and real-TPU ticks (sub-ms).
DEFAULT_LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)

#: Events kept in memory before older ones are dropped (dropped count is
#: tracked in the ``obs_events_dropped_total`` counter).
MAX_EVENTS = 200_000


def _fmt(v: float) -> str:
    """Prometheus number formatting: integers without the trailing .0."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _escape_label(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (v.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    """Prometheus HELP text escaping: backslash and newline only."""
    return v.replace("\\", "\\\\").replace("\n", "\\n")


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict = {}
        self._lock = threading.Lock()

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def _series_name(self, key: tuple) -> str:
        return ",".join(f'{k}="{v}"' for k, v in zip(self.labelnames, key))

    def _prom_series_name(self, key: tuple) -> str:
        """Like :meth:`_series_name` but with label values escaped per the
        Prometheus exposition format (snapshot keys stay raw)."""
        return ",".join(f'{k}="{_escape_label(v)}"'
                        for k, v in zip(self.labelnames, key))

    def items(self) -> list[tuple[tuple, object]]:
        """Sorted (label-key tuple, value) pairs."""
        with self._lock:
            return sorted(self._series.items())

    def series(self) -> dict[str, object]:
        """{'lbl="v",...': value} in sorted-series order ('' = unlabeled)."""
        return {self._series_name(k): v for k, v in self.items()}


class Counter(_Metric):
    """Monotone float counter; ``inc`` only (negative increments rejected)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counter increment < 0")
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + float(amount)

    def get(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))

    def total(self) -> float:
        """Sum across every label series."""
        with self._lock:
            return float(sum(self._series.values()))


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def get(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative bucket counts + sum + count.

    Bucket edges are frozen at creation (deterministic across runs); the
    implicit ``+Inf`` bucket always exists.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames,
                 buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        edges = tuple(sorted(float(b) for b in buckets))
        if len(set(edges)) != len(edges) or not edges:
            raise ValueError(f"{name}: bucket edges must be unique, non-empty")
        self.buckets = edges

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        v = float(value)
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = {"buckets": [0] * (len(self.buckets) + 1),
                      "sum": 0.0, "count": 0}
                self._series[key] = st
            i = len(self.buckets)
            for j, edge in enumerate(self.buckets):
                if v <= edge:
                    i = j
                    break
            st["buckets"][i] += 1
            st["sum"] += v
            st["count"] += 1

    def get(self, **labels) -> dict:
        st = self._series.get(self._key(labels))
        if st is None:
            return {"buckets": [0] * (len(self.buckets) + 1),
                    "sum": 0.0, "count": 0}
        return {"buckets": list(st["buckets"]), "sum": st["sum"],
                "count": st["count"]}

    def cumulative(self, **labels) -> dict[str, int]:
        """{'le_edge': cumulative count, ..., '+Inf': total}."""
        st = self.get(**labels)
        out, acc = {}, 0
        for edge, n in zip(self.buckets, st["buckets"]):
            acc += n
            out[_fmt(edge)] = acc
        out["+Inf"] = acc + st["buckets"][-1]
        return out

    def quantile(self, q: float, **labels) -> float:
        """Estimate the q-quantile from the fixed cumulative buckets.

        Prometheus ``histogram_quantile`` semantics: linear interpolation
        inside the bucket holding the ``q * count``-th observation (lower
        bound of the first bucket is 0 — these record non-negative
        latencies). Observations in the ``+Inf`` overflow bucket clamp to
        the highest finite edge (the honest answer without raw values).
        NaN when the series is empty.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"{self.name}: quantile q={q} not in [0, 1]")
        st = self.get(**labels)
        return self._quantile_of(st, q)

    def _quantile_of(self, st: dict, q: float) -> float:
        if st["count"] == 0:
            return float("nan")
        target = q * st["count"]
        cum, lo = 0, 0.0
        for edge, n in zip(self.buckets, st["buckets"]):
            if n and cum + n >= target:
                return lo + (edge - lo) * (target - cum) / n
            cum += n
            lo = edge
        return self.buckets[-1]  # overflow bucket: clamp to last edge

    def quantiles(self, qs=(0.5, 0.95, 0.99), **labels) -> dict[str, float]:
        """{'p50': v, 'p95': v, 'p99': v} (the snapshot convention)."""
        st = self.get(**labels)
        return {f"p{round(q * 100):d}": self._quantile_of(st, q)
                for q in qs}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """A namespace of metrics + an event log (the JSONL trace).

    ``clock`` is the monotonic time source used to stamp events (``ts``)
    and to time spans/device timers — injectable so tests can drive a
    deterministic fake clock through the whole telemetry pipeline
    (``time.perf_counter`` by default; its origin is arbitrary, only
    deltas and relative placement on the timeline are meaningful).
    """

    def __init__(self, clock=None):
        self._metrics: dict[str, _Metric] = {}
        self._events: list[dict] = []
        self._seq = 0
        self._dropped = 0
        self._lock = threading.Lock()
        self._clock = clock if clock is not None else time.perf_counter

    def now(self) -> float:
        """Current reading of this registry's monotonic clock."""
        return self._clock()

    # -- metric creation (get-or-create; shape must match) ------------------
    def _get(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, tuple(labelnames), **kw)
                self._metrics[name] = m
                return m
        if type(m) is not cls or m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} re-declared as {cls.kind} "
                f"labels={tuple(labelnames)} (was {m.kind} "
                f"labels={m.labelnames})")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    # -- events (JSONL export) ---------------------------------------------
    def emit(self, event: dict) -> None:
        """Append one event (a JSON-able dict). ``seq`` is added here, and
        ``ts`` (the registry clock reading) unless the caller already
        stamped one — spans stamp their START time."""
        ts = round(self.now(), 6)
        with self._lock:
            self._seq += 1
            ev = {"seq": self._seq, "ts": ts, **event}
            self._events.append(ev)
            if len(self._events) > MAX_EVENTS:
                del self._events[: len(self._events) - MAX_EVENTS]
                self._dropped += 1

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def write_events_jsonl(self, path: str, *,
                           final_snapshot: bool = True) -> int:
        """Write the event log as JSONL; optionally append one trailing
        ``{"snapshot": ...}`` line. Returns the number of lines written."""
        evs = self.events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev) + "\n")
            if final_snapshot:
                f.write(json.dumps({"snapshot": self.snapshot()}) + "\n")
        return len(evs) + int(final_snapshot)

    # -- export -------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able view: {"counters": {name: {series: v}}, "gauges": ...,
        "histograms": {name: {series: {"buckets": {le: n}, "sum", "count",
        "quantiles": {"p50"/"p95"/"p99": v}}}}, "events_total": n}."""
        out = {"counters": {}, "gauges": {}, "histograms": {},
               "events_total": self._seq, "events_dropped": self._dropped}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out["histograms"][name] = {
                    sk: {"buckets": dict(zip(map(_fmt, m.buckets),
                                             _cum(st["buckets"])))
                         | {"+Inf": sum(st["buckets"])},
                         "sum": st["sum"], "count": st["count"],
                         "quantiles": {
                             k: round(v, 9)
                             for k, v in zip(
                                 ("p50", "p95", "p99"),
                                 (m._quantile_of(st, q)
                                  for q in (0.5, 0.95, 0.99)))}}
                    for sk, st in m.series().items()}
            else:
                out[m.kind + "s"][name] = dict(m.series())
        return out

    def prometheus_text(self) -> str:
        """Prometheus exposition format, deterministically ordered."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, st in m.items():
                    sk = m._prom_series_name(key)
                    pre = sk + "," if sk else ""
                    acc = 0
                    for edge, n in zip(m.buckets, st["buckets"]):
                        acc += n
                        lines.append(
                            f'{name}_bucket{{{pre}le="{_fmt(edge)}"}} '
                            f"{acc}")
                    lines.append(f'{name}_bucket{{{pre}le="+Inf"}} '
                                 f"{acc + st['buckets'][-1]}")
                    suffix = f"{{{sk}}}" if sk else ""
                    lines.append(f"{name}_sum{suffix} {_fmt(st['sum'])}")
                    lines.append(f"{name}_count{suffix} {st['count']}")
            else:
                for key, v in m.items():
                    sk = m._prom_series_name(key)
                    suffix = f"{{{sk}}}" if sk else ""
                    lines.append(f"{name}{suffix} {_fmt(v)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._events.clear()
            self._seq = 0
            self._dropped = 0


def _cum(buckets: list[int]) -> list[int]:
    out, acc = [], 0
    for n in buckets[:-1]:
        acc += n
        out.append(acc)
    return out


# ---------------------------------------------------------------------------
# Registry resolution: process default + scoped overrides
# ---------------------------------------------------------------------------

_DEFAULT = Registry()
_STACK: list[Registry] = []


def default_registry() -> Registry:
    """The process-global registry (what serve/benchmark CLIs snapshot)."""
    return _DEFAULT


def current_registry() -> Registry:
    """Registry instrumentation writes to: innermost ``use_registry``
    override, else the process default."""
    return _STACK[-1] if _STACK else _DEFAULT


@contextlib.contextmanager
def use_registry(registry: Registry):
    """Scoped override of :func:`current_registry` (test/bench isolation)."""
    _STACK.append(registry)
    try:
        yield registry
    finally:
        _STACK.pop()
