"""Perfetto / chrome://tracing export of the registry event log.

Turns the structured engine lifecycle events (``repro.serving.engine``)
into a ``traceEvents`` JSON document loadable in https://ui.perfetto.dev
(or chrome://tracing): drag the file in, or "Open trace file". Tracks:

* **engine phases** (pid 1, tid 0): one slice per host-side tick phase —
  admit / prefill / decode / retire — from span events carrying
  ``phase`` + ``ts``/``seconds``; jit retraces show as instant markers.
* **request slots** (pid 2, tid = slot index): each admitted request's
  full lifecycle on the slot it occupied — a ``queued`` slice (submit →
  admit), a ``prefill`` slice, one ``decode`` slice per tick the request
  was live in (from the tick event's ``slot_rids``), a TTFT instant at
  the first generated token, and a retire instant carrying token count +
  TPOT. Slice names lead with the request's ``r<rid>`` so Perfetto's
  search/aggregation groups a request across ticks. Non-``ok`` retires
  render as DISTINCT markers (``r<rid> retire:nan`` / ``:timeout`` /
  ``:cancelled`` / ``:error``); retires that never held a slot
  (``rejected``, queued timeouts/cancels) land on the engine lane.
  Fault-tolerance events show on the engine lane too:
  ``kernel_failure:<phase>``, ``fallback:<reason>``, ``slow_tick``, and
  ``engine abort:<reason>`` instants.
* **counter tracks** (pid 1): ``moe_m_tiles`` (cumulative executed vs
  dense-total grouped-GEMM m-tiles from the live routing sink) and
  ``qgemm_calls`` (trace-time wrapper calls — flat in steady state, a
  visible staircase on retraces), sampled at each tick boundary from the
  engine's ``counters`` events.

Timestamps are the registry clock (``Registry.now``, perf_counter by
default) converted to microseconds; only relative placement is
meaningful. Everything here is a pure function of ``Registry.events()``
— deterministic given a deterministic clock, which is what the golden
test injects. Events lacking ``ts`` (pre-PR-9 logs) are skipped.
"""
from __future__ import annotations

import json

from .metrics import Registry

#: Perfetto process ids (purely presentational grouping).
PID_ENGINE = 1
PID_REQUESTS = 2

_ENGINE_TID = 0


def _meta(pid: int, name: str, tid: int | None = None,
          tname: str | None = None) -> list[dict]:
    out = [{"ph": "M", "pid": pid, "name": "process_name",
            "args": {"name": name}}]
    if tid is not None:
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name", "args": {"name": tname}})
    return out


def _slice(pid: int, tid: int, name: str, ts_us: float, dur_us: float,
           args: dict | None = None) -> dict:
    ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
          "ts": round(ts_us, 3), "dur": round(dur_us, 3)}
    if args:
        ev["args"] = args
    return ev


def _instant(pid: int, tid: int, name: str, ts_us: float,
             args: dict | None = None) -> dict:
    ev = {"ph": "i", "s": "t", "pid": pid, "tid": tid, "name": name,
          "ts": round(ts_us, 3)}
    if args:
        ev["args"] = args
    return ev


def _counter(name: str, ts_us: float, values: dict) -> dict:
    return {"ph": "C", "pid": PID_ENGINE, "name": name,
            "ts": round(ts_us, 3), "args": values}


def trace_events(events: list[dict]) -> list[dict]:
    """Convert a registry event list into chrome-tracing ``traceEvents``.

    Pure and deterministic: output order is metadata first, then source
    event order (the registry's ``seq`` order).
    """
    submits = {ev["rid"]: ev for ev in events
               if ev.get("ev") == "submit" and "ts" in ev}
    out: list[dict] = list(_meta(PID_ENGINE, "engine", _ENGINE_TID,
                                 "phases"))
    out += _meta(PID_REQUESTS, "requests")
    slots_named: set[int] = set()

    def name_slot(slot: int) -> None:
        if slot not in slots_named:
            slots_named.add(slot)
            out.extend(_meta(PID_REQUESTS, "requests", slot,
                             f"slot {slot}")[1:])

    for ev in events:
        kind = ev.get("ev")
        ts = ev.get("ts")
        if ts is None:
            continue
        us = ts * 1e6
        dur = ev.get("seconds", 0.0) * 1e6

        if kind in ("phase", "admit", "tick") and "phase" in ev:
            args = {k: ev[k] for k in ("tick", "slots_active",
                                       "queue_depth", "rid", "slot",
                                       "prompt_len") if k in ev}
            out.append(_slice(PID_ENGINE, _ENGINE_TID, ev["phase"],
                              us, dur, args or None))

        if kind == "admit" and "slot" in ev:
            rid, slot = ev["rid"], ev["slot"]
            name_slot(slot)
            sub = submits.get(rid)
            if sub is not None and sub["ts"] <= ts:
                out.append(_slice(PID_REQUESTS, slot, f"r{rid} queued",
                                  sub["ts"] * 1e6, us - sub["ts"] * 1e6))
            out.append(_slice(
                PID_REQUESTS, slot, f"r{rid} prefill", us, dur,
                {"rid": rid, "prompt_len": ev.get("prompt_len"),
                 "trace_id": ev.get("trace_id")}))
            ttft = ev.get("ttft_s")
            if ttft is not None:
                out.append(_instant(
                    PID_REQUESTS, slot, f"r{rid} TTFT", us + dur,
                    {"ttft_ms": round(ttft * 1e3, 3)}))

        if kind == "tick":
            for slot, rid in enumerate(ev.get("slot_rids", ())):
                if rid is None or rid < 0:
                    continue
                name_slot(slot)
                out.append(_slice(PID_REQUESTS, slot, f"r{rid} decode",
                                  us, dur, {"tick": ev.get("tick")}))

        if kind == "retire":
            slot = ev.get("slot")
            outcome = ev.get("outcome")
            # error/timeout/nan/... retires get DISTINCT marker names so
            # they're searchable in Perfetto apart from clean finishes
            suffix = "" if outcome in (None, "ok") else f":{outcome}"
            args = {"tokens": ev.get("tokens"),
                    "tpot_ms": round(ev.get("tpot_s", 0.0) * 1e3, 3),
                    "trace_id": ev.get("trace_id")}
            if outcome is not None:
                args["outcome"] = outcome
            if slot is not None:
                name_slot(slot)
                out.append(_instant(
                    PID_REQUESTS, slot, f"r{ev['rid']} retire{suffix}",
                    us, args))
            elif suffix:
                # rejected/cancelled/timed-out before ever holding a slot
                out.append(_instant(
                    PID_ENGINE, _ENGINE_TID,
                    f"r{ev['rid']} retire{suffix}", us, args))

        if kind == "counters":
            out.append(_counter("moe_m_tiles", us,
                                {"executed": ev.get("moe_executed", 0),
                                 "total": ev.get("moe_total", 0)}))
            out.append(_counter("qgemm_calls", us,
                                {"calls": ev.get("qgemm_calls", 0)}))

        if kind == "trace":
            out.append(_instant(PID_ENGINE, _ENGINE_TID,
                                f"jit trace:{ev.get('fn', '?')}", us,
                                {"count": ev.get("engine_count")}))

        if kind == "fallback":
            out.append(_instant(
                PID_ENGINE, _ENGINE_TID,
                f"fallback:{ev.get('reason', '?')}", us,
                {"from": ev.get("from"), "to": ev.get("to"),
                 "fallbacks": ev.get("fallbacks")}))

        if kind == "kernel_failure":
            out.append(_instant(
                PID_ENGINE, _ENGINE_TID,
                f"kernel_failure:{ev.get('phase', '?')}", us,
                {"streak": ev.get("streak"), "error": ev.get("error")}))

        if kind == "slow_tick":
            out.append(_instant(
                PID_ENGINE, _ENGINE_TID, "slow_tick", us,
                {"tick": ev.get("tick"), "seconds": ev.get("seconds"),
                 "median_s": ev.get("median_s")}))

        if kind == "abort":
            out.append(_instant(
                PID_ENGINE, _ENGINE_TID,
                f"engine abort:{ev.get('reason', '?')}", us,
                {"error": ev.get("error")}))
    return out


def build_trace(registry: Registry) -> dict:
    """The full Perfetto-loadable document for a registry's event log."""
    return {"traceEvents": trace_events(registry.events()),
            "displayTimeUnit": "ms"}


def write_trace(path: str, registry: Registry) -> int:
    """Write the trace JSON; returns the number of traceEvents written."""
    doc = build_trace(registry)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])
