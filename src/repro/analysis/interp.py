"""Interval dataflow interpreter over traced jaxprs (qlint pass 1).

``analyze_fn(fn, args, input_ranges=...)`` traces ``fn`` with
``jax.make_jaxpr`` and abstractly interprets the jaxpr, propagating one
:class:`~repro.analysis.intervals.Interval` per value. The interpreter
understands ``pallas_call`` natively:

  * the kernel body jaxpr is entered with per-operand intervals seeded
    from the wrapper-level dataflow (so e.g. ``sa / alpha`` folding is
    seen by the analysis);
  * kernel refs (inputs, outputs, scratch) are modeled as mutable cells
    holding intervals; ``get``/``swap`` read/replace them, ``cond``
    (``pl.when``) forks the cell store per branch and joins afterwards;
  * the **minor (innermost) grid axis is interpreted exactly**: the body
    runs once per index with ``program_id`` pinned to that index and the
    cell store carried across steps — this models accumulator revisits
    (the K-group loop of the quantized GEMMs) without widening, so
    ``pl.when(k == 0)`` resets resolve precisely. All other grid axes
    are abstracted to their full ``[0, extent-1]`` index range.

Soundness notes
---------------
* Unknown primitives fall back to the output dtype's full range and are
  recorded as ``unknown-prim`` events (never silently precise).
* Integer ``add/sub/mul/dot_general/reduce_sum/cumsum`` whose result
  interval escapes the result dtype emit an ``int-overflow`` event; the
  *unclamped* interval keeps propagating so downstream magnitudes stay
  worst-case. ``shift_left`` wrap is the one sanctioned wrap idiom (the
  int4 nibble unpack shifts through the sign bit on purpose): it clamps
  to the dtype range without an event.
* Integer-narrowing ``convert_element_type`` whose input interval does
  not fit the target dtype emits ``narrowing-convert`` (lint rule R2);
  in-range narrowing (e.g. unpacked nibbles int32->int8) is clean.
* ``swap`` replaces the cell interval (all kernel stores in this repo
  cover the full block); reads of never-written cells fall back to the
  dtype range and emit ``uninit-read``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import numpy as np
from jax._src import source_info_util

from .intervals import Interval

ARITH_PRIMS = frozenset(
    {"add", "sub", "mul", "dot_general", "reduce_sum", "cumsum"})
PASSTHRU_PRIMS = frozenset({
    "reshape", "transpose", "squeeze", "slice", "dynamic_slice",
    "broadcast_in_dim", "rev", "gather", "copy", "copy_p", "real",
    "expand_dims", "stop_gradient", "device_put", "sharding_constraint",
})
COMPARE_PRIMS = frozenset({"eq", "ne", "lt", "le", "gt", "ge"})
MAX_GRID_ITERS = 1024

DATA = "data"  # input_ranges sentinel: seed from dtype, not array values


def _where(eqn) -> str:
    try:
        return source_info_util.summarize(eqn.source_info)
    except Exception:  # pragma: no cover - best effort only
        return "<unknown>"


@dataclasses.dataclass(frozen=True)
class Event:
    """Analyzer-emitted fact consumed by lint rules / certificates."""

    kind: str  # int-overflow | narrowing-convert | uninit-read | unknown-prim
    prim: str
    detail: str
    interval: Interval | None
    where: str


@dataclasses.dataclass(frozen=True)
class EqnRecord:
    """One interpreted equation with its value intervals (lint input)."""

    prim: str
    scope: str  # "" = wrapper level, "pallas:<name>" = kernel body
    out_dtype: str
    out_interval: Interval
    in_dtypes: tuple
    in_intervals: tuple
    params: dict
    where: str
    eqn_id: int  # identity token: same eqn re-interpreted -> same id


@dataclasses.dataclass
class PallasRecord:
    """Structural info for one pallas_call (lint rules R4/R5)."""

    name: str
    grid: tuple
    grid_mapping: Any
    operand_intervals: list  # seeds, aligned with eqn invars


@dataclasses.dataclass
class Analysis:
    records: list
    events: list
    pallas: list
    out_intervals: list

    @property
    def int_accum_bound(self) -> float:
        """Max |value| over integer arithmetic results — the worst-case
        magnitude any integer accumulator chain can reach."""
        b = 0.0
        for r in self.records:
            if r.prim in ARITH_PRIMS and np.dtype(r.out_dtype).kind in "iu":
                b = max(b, r.out_interval.max_abs())
        return b

    def events_of(self, *kinds) -> list:
        return [e for e in self.events if e.kind in kinds]


class _Ref:
    """Identity handle for a pallas ref; the cell store maps it to an
    Interval (or None = never written)."""

    __slots__ = ("dtype",)

    def __init__(self, dtype):
        self.dtype = dtype


def _is_ref_aval(aval) -> bool:
    return hasattr(aval, "inner_aval")


def _aval_dtype(aval):
    return getattr(aval, "inner_aval", aval).dtype


class _Interp:
    def __init__(self):
        self.records: list[EqnRecord] = []
        self.events: list[Event] = []
        self.pallas: list[PallasRecord] = []
        self._scope: list[str] = [""]
        self._grid: list[tuple] = []  # (grid, minor_index | None)

    # -- plumbing -----------------------------------------------------------

    def note(self, kind, eqn, detail, interval=None):
        self.events.append(
            Event(kind, eqn.primitive.name, detail, interval, _where(eqn)))

    def read(self, env, atom):
        if isinstance(atom, jax.core.Literal):
            v = atom.val
            if hasattr(v, "shape"):
                return Interval.of_array(v)
            return Interval.point(v)
        return env[atom]

    def run(self, jaxpr, consts, invals, store) -> list:
        env: dict = {}
        for v, c in zip(jaxpr.constvars, consts):
            env[v] = (Interval.of_array(c) if hasattr(c, "shape")
                      else Interval.point(c))
        for v, val in zip(jaxpr.invars, invals):
            env[v] = val
        for eqn in jaxpr.eqns:
            ins = [self.read(env, a) for a in eqn.invars]
            outs = self.eqn(eqn, ins, store)
            for ov, o in zip(eqn.outvars, outs):
                env[ov] = o
        return [self.read(env, a) for a in jaxpr.outvars]

    # -- equation dispatch --------------------------------------------------

    def eqn(self, eqn, ins, store) -> list:
        name = eqn.primitive.name
        handler = getattr(type(self), f"_p_{name}", None)
        if handler is None:
            handler = _GENERIC.get(name)
        if handler is None:
            outs = [Interval.from_dtype(_aval_dtype(v.aval))
                    for v in eqn.outvars]
            self.note("unknown-prim", eqn, f"no transfer fn for '{name}'")
        else:
            outs = handler(self, eqn, ins, store)
        outs = list(outs)
        # overflow surveillance on the integer arithmetic chain
        if name in ARITH_PRIMS and eqn.outvars:
            dt = _aval_dtype(eqn.outvars[0].aval)
            if np.dtype(dt).kind in "iu" and outs and \
                    not outs[0].fits_dtype(dt):
                self.note("int-overflow", eqn,
                          f"{name} result {outs[0]} exceeds {np.dtype(dt)}",
                          outs[0])
        for ov, o in zip(eqn.outvars, outs):
            if isinstance(o, Interval):
                self.records.append(EqnRecord(
                    name, self._scope[-1], str(_aval_dtype(ov.aval)), o,
                    tuple(str(_aval_dtype(a.aval)) for a in eqn.invars),
                    tuple(i for i in ins if isinstance(i, Interval)),
                    eqn.params, _where(eqn), id(eqn)))
        return outs

    # -- structured control flow -------------------------------------------

    def _call(self, eqn, ins, store):
        sub = None
        for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = eqn.params.get(k, sub)
            if sub is not None:
                break
        if sub is None:  # pragma: no cover
            return [Interval.from_dtype(_aval_dtype(v.aval))
                    for v in eqn.outvars]
        if hasattr(sub, "jaxpr"):  # ClosedJaxpr
            return self.run(sub.jaxpr, sub.consts, ins, store)
        return self.run(sub, (), ins, store)

    def _p_pjit(self, eqn, ins, store):
        # jnp's floor_divide wrapper lowers to div + a sign/rem-coupled
        # correction that a non-relational domain can't prune (it would
        # widen r // G to [r//G - 1, r//G], flagging every grouped-head
        # index map). Floor division IS interval-exact — compute it.
        if (eqn.params.get("name") == "floor_divide" and len(ins) == 2
                and all(isinstance(i, Interval) for i in ins)
                and np.dtype(_out_dtype(eqn)).kind in "iu"):
            return [ins[0].floordiv(ins[1])]
        return self._call(eqn, ins, store)

    _p_closed_call = _call
    _p_core_call = _call
    _p_remat2 = _call
    _p_checkpoint = _call

    def _p_custom_jvp_call(self, eqn, ins, store):
        sub = eqn.params.get("call_jaxpr")
        if sub is None:
            return [Interval.from_dtype(_aval_dtype(v.aval))
                    for v in eqn.outvars]
        return self.run(sub.jaxpr, sub.consts, ins, store)

    _p_custom_vjp_call = _p_custom_jvp_call
    _p_custom_vjp_call_jaxpr = _p_custom_jvp_call

    def _p_cond(self, eqn, ins, store):
        branches = eqn.params["branches"]
        idx, ops = ins[0], ins[1:]
        if idx.is_point() and 0 <= int(idx.lo) < len(branches):
            take = [branches[int(idx.lo)]]
        else:
            lo = max(int(idx.lo), 0)
            hi = min(int(idx.hi), len(branches) - 1)
            take = [branches[i] for i in range(lo, hi + 1)] or list(branches)
        out_join: list | None = None
        stores = []
        for br in take:
            st = dict(store)
            outs = self.run(br.jaxpr, br.consts, ops, st)
            stores.append(st)
            if out_join is None:
                out_join = outs
            else:
                out_join = [a.union(b) if isinstance(a, Interval)
                            and isinstance(b, Interval) else a
                            for a, b in zip(out_join, outs)]
        # join cell stores (None = bottom, absorbed by union)
        keys = set().union(*[set(s) for s in stores]) if stores else set()
        for k in keys:
            vals = [s.get(k) for s in stores]
            have = [v for v in vals if v is not None]
            if len(have) < len(vals):  # some branch left it unwritten:
                have.append(store.get(k))  # pre-state survives
            have = [v for v in have if v is not None]
            store[k] = _union_all(have) if have else None
        return out_join or []

    # -- pallas -------------------------------------------------------------

    def _p_program_id(self, eqn, ins, store):
        axis = eqn.params["axis"]
        if not self._grid:
            return [Interval.point(0)]
        grid, minor_val = self._grid[-1]
        if axis == len(grid) - 1 and minor_val is not None:
            return [Interval.point(minor_val)]
        return [Interval(0.0, float(max(grid[axis] - 1, 0)))]

    def _p_num_programs(self, eqn, ins, store):
        grid = self._grid[-1][0] if self._grid else (1,)
        return [Interval.point(grid[eqn.params["axis"]])]

    def _p_get(self, eqn, ins, store):
        ref = ins[0]
        assert isinstance(ref, _Ref), "get on non-ref"
        val = store.get(ref)
        if val is None:
            self.note("uninit-read", eqn,
                      "read of never-written output/scratch ref")
            val = Interval.from_dtype(ref.dtype)
        return [val]

    def _p_swap(self, eqn, ins, store):
        ref, val = ins[0], ins[1]
        assert isinstance(ref, _Ref), "swap on non-ref"
        old = store.get(ref)
        store[ref] = val
        return [old if old is not None else Interval.from_dtype(ref.dtype)]

    def _p_pallas_call(self, eqn, ins, store):
        gm = eqn.params["grid_mapping"]
        body = eqn.params["jaxpr"]
        name = str(eqn.params.get("name_and_src_info", "kernel")).split(" ")[0]
        grid = tuple(int(g) for g in gm.grid) or (1,)
        n_idx, n_in = gm.num_index_operands, gm.num_inputs
        n_out = gm.num_outputs
        self.pallas.append(PallasRecord(name, grid, gm, list(ins)))

        handles, st = [], {}
        for i, v in enumerate(body.invars):
            h = _Ref(_aval_dtype(v.aval))
            handles.append(h)
            st[h] = ins[i] if i < n_idx + n_in else None
        minor = grid[-1]
        if minor > MAX_GRID_ITERS:
            self.note("unknown-prim", eqn,
                      f"minor grid axis {minor} > {MAX_GRID_ITERS}: "
                      "iterating abstractly (bounds may be loose)")
        self._scope.append(f"pallas:{name}")
        consts = getattr(body, "constvars", ())
        cvals = [Interval.from_dtype(_aval_dtype(c.aval)) for c in consts]
        try:
            for k in range(min(minor, MAX_GRID_ITERS)):
                self._grid.append(
                    (grid, k if minor <= MAX_GRID_ITERS else None))
                try:
                    self.run(body, cvals, handles, st)
                finally:
                    self._grid.pop()
        finally:
            self._scope.pop()

        outs = []
        for j in range(n_out):
            h = handles[n_idx + n_in + j]
            outs.append(st[h] if st[h] is not None
                        else Interval.from_dtype(h.dtype))
        return outs


def _union_all(vals):
    out = vals[0]
    for v in vals[1:]:
        out = out.union(v)
    return out


# ---------------------------------------------------------------------------
# Generic (store-free) transfer functions
# ---------------------------------------------------------------------------


def _out_dtype(eqn):
    return _aval_dtype(eqn.outvars[0].aval)


def _h_dot_general(self, eqn, ins, store):
    (lc, _), _ = eqn.params["dimension_numbers"]
    lhs_shape = eqn.invars[0].aval.shape
    n = 1
    for d in lc:
        n *= lhs_shape[d]
    return [(ins[0] * ins[1]).sum_n(n)]


def _h_reduce_sum(self, eqn, ins, store):
    shape = eqn.invars[0].aval.shape
    n = 1
    for d in eqn.params["axes"]:
        n *= shape[d]
    return [ins[0].sum_n(n)]


def _h_cumsum(self, eqn, ins, store):
    n = eqn.invars[0].aval.shape[eqn.params["axis"]]
    return [ins[0].sum_n(n)]


def _h_convert(self, eqn, ins, store):
    src = _aval_dtype(eqn.invars[0].aval)
    dst = _out_dtype(eqn)
    iv = ins[0]
    if np.dtype(dst).kind in "iu" and not iv.fits_dtype(dst):
        if np.dtype(src).kind in "iu":
            self.note("narrowing-convert", eqn,
                      f"{np.dtype(src)}->{np.dtype(dst)} may truncate "
                      f"{iv}", iv)
        iv = Interval.from_dtype(dst)
    return [iv]


def _h_shift_left(self, eqn, ins, store):
    dt = _out_dtype(eqn)
    if ins[1].is_point():
        s = float(2 ** int(ins[1].lo))
        iv = Interval(ins[0].lo * s, ins[0].hi * s)
        if iv.fits_dtype(dt):
            return [iv]
    # wrapping shift (sanctioned idiom: int4 nibble unpack) -> dtype range
    return [Interval.from_dtype(dt)]


def _h_shift_right_logical(self, eqn, ins, store):
    iv = ins[0]
    if iv.lo >= 0:
        return [iv.shift_right(ins[1])]
    return [Interval.from_dtype(_out_dtype(eqn))]  # sign bits shift in


def _h_div(self, eqn, ins, store):
    if np.dtype(_out_dtype(eqn)).kind in "iu":
        return [ins[0].intdiv(ins[1])]
    return [ins[0].truediv(ins[1])]


def _h_rem(self, eqn, ins, store):
    """XLA rem truncates: the result's sign follows the dividend and
    |result| < |divisor| (and <= |dividend|)."""
    a, b = ins[0], ins[1]
    m = b.max_abs()
    lo = 0.0 if a.lo >= 0 else max(-m, a.lo)
    hi = 0.0 if a.hi <= 0 else min(m, a.hi)
    return [Interval(lo, hi)]


def _h_compare(self, eqn, ins, store):
    a, b = ins[0], ins[1]
    name = eqn.primitive.name
    if name in ("lt", "gt", "le", "ge"):
        x, y = (a, b) if name in ("lt", "le") else (b, a)
        strict = name in ("lt", "gt")
        if (x.hi < y.lo) or (not strict and x.hi <= y.lo):
            return [Interval.point(1)]
        if (x.lo > y.hi) or (strict and x.lo >= y.hi):
            return [Interval.point(0)]
    elif name == "eq":
        if a.is_point() and b.is_point():
            return [Interval.point(1 if a.lo == b.lo else 0)]
        if a.hi < b.lo or a.lo > b.hi:
            return [Interval.point(0)]
    elif name == "ne":
        if a.is_point() and b.is_point():
            return [Interval.point(0 if a.lo == b.lo else 1)]
        if a.hi < b.lo or a.lo > b.hi:
            return [Interval.point(1)]
    return [Interval(0.0, 1.0)]


def _h_bool_and(self, eqn, ins, store):
    if str(_out_dtype(eqn)) != "bool":
        return [Interval.from_dtype(_out_dtype(eqn))]
    a, b = ins[0], ins[1]
    if a.hi == 0 or b.hi == 0:
        return [Interval.point(0)]
    if a.lo == 1 and b.lo == 1:
        return [Interval.point(1)]
    return [Interval(0.0, 1.0)]


def _h_bool_or(self, eqn, ins, store):
    if str(_out_dtype(eqn)) != "bool":
        return [Interval.from_dtype(_out_dtype(eqn))]
    a, b = ins[0], ins[1]
    if a.lo == 1 or b.lo == 1:
        return [Interval.point(1)]
    if a.hi == 0 and b.hi == 0:
        return [Interval.point(0)]
    return [Interval(0.0, 1.0)]


def _h_bool_not(self, eqn, ins, store):
    if str(_out_dtype(eqn)) != "bool":
        return [Interval.from_dtype(_out_dtype(eqn))]
    a = ins[0]
    if a.is_point():
        return [Interval.point(0 if a.lo else 1)]
    return [Interval(0.0, 1.0)]


def _h_integer_pow(self, eqn, ins, store):
    y = eqn.params["y"]
    a = ins[0]
    if y == 2 or (y % 2 == 0 and y >= 0):
        m = a.max_abs() ** y
        lo = 0.0 if a.lo <= 0 <= a.hi else min(abs(a.lo), abs(a.hi)) ** y
        return [Interval(lo, m)]
    if y >= 0:
        return [a.monotone(lambda v: v ** y)]
    return [Interval.top()]


def _safe_mono(f, lo_dom=-math.inf):
    def h(self, eqn, ins, store):
        a = ins[0]
        lo = max(a.lo, lo_dom)
        hi = max(a.hi, lo_dom)
        try:
            return [Interval(f(lo), f(hi))]
        except (ValueError, OverflowError):
            return [Interval.top()]
    return h


def _h_exp(self, eqn, ins, store):
    def e(v):
        if v == math.inf:
            return math.inf
        try:
            return math.exp(v)
        except OverflowError:
            return math.inf
    return [ins[0].monotone(e)]


def _h_iota(self, eqn, ins, store):
    shape = eqn.outvars[0].aval.shape
    d = eqn.params["dimension"]
    return [Interval(0.0, float(max(shape[d] - 1, 0)))]


_GENERIC: dict[str, Callable] = {
    "add": lambda s, e, i, st: [i[0] + i[1]],
    "sub": lambda s, e, i, st: [i[0] - i[1]],
    "mul": lambda s, e, i, st: [i[0] * i[1]],
    "neg": lambda s, e, i, st: [-i[0]],
    "abs": lambda s, e, i, st: [i[0].abs()],
    "sign": lambda s, e, i, st: [Interval(-1.0, 1.0)],
    "max": lambda s, e, i, st: [i[0].maximum(i[1])],
    "min": lambda s, e, i, st: [i[0].minimum(i[1])],
    "clamp": lambda s, e, i, st: [i[1].clamp(i[0], i[2])],
    "round": lambda s, e, i, st: [i[0].monotone(
        lambda v: v if not math.isfinite(v) else float(round(v)))],
    "floor": lambda s, e, i, st: [i[0].monotone(
        lambda v: v if not math.isfinite(v) else math.floor(v))],
    "ceil": lambda s, e, i, st: [i[0].monotone(
        lambda v: v if not math.isfinite(v) else math.ceil(v))],
    "nextafter": lambda s, e, i, st: [i[0]],
    "reduce_max": lambda s, e, i, st: [i[0]],
    "reduce_min": lambda s, e, i, st: [i[0]],
    "reduce_and": lambda s, e, i, st: [Interval(0.0, 1.0)],
    "reduce_or": lambda s, e, i, st: [Interval(0.0, 1.0)],
    "reduce_prod": lambda s, e, i, st: [Interval.top()],
    "argmax": lambda s, e, i, st: [Interval(
        0.0, float(max(np.prod([e.invars[0].aval.shape[d]
                                for d in e.params["axes"]]) - 1, 0)))],
    "select_n": lambda s, e, i, st: [_union_all(i[1:])],
    "concatenate": lambda s, e, i, st: [_union_all(i)],
    "pad": lambda s, e, i, st: [i[0].union(i[1])],
    "dynamic_update_slice": lambda s, e, i, st: [i[0].union(i[1])],
    "rem": _h_rem,
    "dot_general": _h_dot_general,
    "reduce_sum": _h_reduce_sum,
    "cumsum": _h_cumsum,
    "convert_element_type": _h_convert,
    "shift_left": _h_shift_left,
    "shift_right_arithmetic":
        lambda s, e, i, st: [i[0].shift_right(i[1])],
    "shift_right_logical": _h_shift_right_logical,
    "div": _h_div,
    "eq": _h_compare, "ne": _h_compare, "lt": _h_compare,
    "le": _h_compare, "gt": _h_compare, "ge": _h_compare,
    "and": _h_bool_and, "or": _h_bool_or, "not": _h_bool_not,
    "xor": lambda s, e, i, st: [Interval(0.0, 1.0)]
        if str(_out_dtype(e)) == "bool"
        else [Interval.from_dtype(_out_dtype(e))],
    "integer_pow": _h_integer_pow,
    "exp": _h_exp,
    "exp2": _h_exp,
    "log": _safe_mono(lambda v: math.log(v) if v > 0 else -math.inf),
    "sqrt": _safe_mono(lambda v: math.sqrt(max(v, 0.0))),
    "rsqrt": lambda s, e, i, st: [Interval(0.0, math.inf)],
    "tanh": lambda s, e, i, st: [Interval(-1.0, 1.0)],
    "logistic": lambda s, e, i, st: [Interval(0.0, 1.0)],
    "erf": lambda s, e, i, st: [Interval(-1.0, 1.0)],
    "is_finite": lambda s, e, i, st: [Interval(0.0, 1.0)],
    "iota": _h_iota,
    "square": lambda s, e, i, st: [Interval(
        0.0 if i[0].lo <= 0 <= i[0].hi
        else min(abs(i[0].lo), abs(i[0].hi)) ** 2,
        i[0].max_abs() ** 2)],
}
for _p in PASSTHRU_PRIMS:
    _GENERIC[_p] = lambda s, e, i, st: [i[0]]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def analyze_jaxpr(closed_jaxpr, in_intervals) -> Analysis:
    """Interpret a ClosedJaxpr with the given input intervals."""
    it = _Interp()
    outs = it.run(closed_jaxpr.jaxpr, closed_jaxpr.consts,
                  list(in_intervals), {})
    return Analysis(it.records, it.events, it.pallas, outs)


def analyze_fn(fn, *args, input_ranges: dict | None = None) -> Analysis:
    """Trace ``fn(*args)`` and run the interval pass.

    ``args`` must be a flat sequence of arrays/scalars. Each input is
    seeded with the tight interval of its concrete values (appropriate
    for static operands: weights, scales, row counts); pass
    ``input_ranges={i: Interval(..) | interp.DATA}`` to widen input
    ``i`` to a contract range (``DATA`` = full dtype range) for
    data-dependent operands like activations.
    """
    closed = jax.make_jaxpr(fn)(*args)
    ranges = input_ranges or {}
    seeds = []
    for i, a in enumerate(args):
        r = ranges.get(i)
        if isinstance(r, Interval):
            seeds.append(r)
        elif r == DATA:
            seeds.append(Interval.from_dtype(np.asarray(a).dtype))
        else:
            seeds.append(Interval.of_array(a))
    return analyze_jaxpr(closed, seeds)


def analyze_index_map(index_map_closed_jaxpr, grid, prefetch_ranges,
                      n_scalar_args: int) -> list:
    """Interval-evaluate a BlockSpec index map over the whole grid.

    ``prefetch_ranges`` seed the trailing scalar-prefetch ref operands
    (e.g. ragged row counts, seeded from the wrapper's documented
    ``[0, C]`` clamp contract). Returns output block-index intervals.
    """
    it = _Interp()
    jaxpr = index_map_closed_jaxpr.jaxpr
    seeds: list = [Interval(0.0, float(max(g - 1, 0))) for g in grid]
    store: dict = {}
    invals: list = []
    for i, v in enumerate(jaxpr.invars):
        if _is_ref_aval(v.aval):
            h = _Ref(_aval_dtype(v.aval))
            pi = i - n_scalar_args
            store[h] = (prefetch_ranges[pi]
                        if 0 <= pi < len(prefetch_ranges)
                        else Interval.from_dtype(_aval_dtype(v.aval)))
            invals.append(h)
        else:
            invals.append(seeds[i] if i < len(seeds) else
                          Interval.from_dtype(_aval_dtype(v.aval)))
    return it.run(jaxpr, index_map_closed_jaxpr.consts, invals, store)
