"""Deliberately broken kernels — regression fixtures for qlint.

Each fixture seeds exactly one defect class the lint/certify passes must
catch; ``python -m repro.analysis.qlint --fixtures`` runs ONLY these and
must exit nonzero (tested in tests/test_qlint.py). They are never
executed, only traced.
"""
from __future__ import annotations

import numpy as np

from .intervals import Interval
from .registry import KernelEntry

_M, _K, _N = 8, 256, 128


def _pallas(kernel, out_shape, grid, in_specs, out_specs):
    import jax
    from jax.experimental import pallas as pl

    def fn(*args):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(*out_shape),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            interpret=True,
        )(*args)

    return fn


def _whole(shape):
    from jax.experimental import pallas as pl

    rank = len(shape)
    return pl.BlockSpec(shape, lambda *_: (0,) * rank)


def _ints(shape, dtype=np.int8):
    import jax.numpy as jnp

    return jnp.asarray(np.zeros(shape, dtype))


def _fx_fp32_dot():
    """Float MXU dot on a path registered as integer-scale (Eq. 1 crept
    back in) -> float-accum-on-is-path."""
    import jax
    import jax.numpy as jnp

    def kernel(x_ref, w_ref, o_ref):
        x = x_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] = jax.lax.dot_general(
            x, w, dimension_numbers=(((1,), (0,)), ((), ())))

    fn = _pallas(kernel, (((_M, _N)), jnp.float32), (1,),
                 [_whole((_M, _K)), _whole((_K, _N))], _whole((_M, _N)))
    args = (_ints((_M, _K)), _ints((_K, _N)))
    return fn, args, {0: Interval(-127, 127), 1: Interval(-7, 7)}


def _fx_no_preferred():
    """Integer dot without preferred_element_type=int32: XLA accumulates
    MXU partials in int8 -> int-dot-preferred-type (+ overflow events)."""
    import jax
    import jax.numpy as jnp

    def kernel(x_ref, w_ref, o_ref):
        o_ref[...] = jax.lax.dot_general(
            x_ref[...], w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())))

    fn = _pallas(kernel, ((_M, _N), jnp.int8), (1,),
                 [_whole((_M, _K)), _whole((_K, _N))], _whole((_M, _N)))
    args = (_ints((_M, _K)), _ints((_K, _N)))
    return fn, args, {0: Interval(-127, 127), 1: Interval(-7, 7)}


def _fx_narrowing():
    """int32 accumulator squeezed through int16 before the epilogue ->
    narrowing-convert."""
    import jax
    import jax.numpy as jnp

    def kernel(x_ref, w_ref, o_ref):
        acc = jax.lax.dot_general(
            x_ref[...], w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        o_ref[...] = acc.astype(jnp.int16).astype(jnp.int32)

    fn = _pallas(kernel, ((_M, _N), jnp.int32), (1,),
                 [_whole((_M, _K)), _whole((_K, _N))], _whole((_M, _N)))
    args = (_ints((_M, _K)), _ints((_K, _N)))
    return fn, args, {0: Interval(-127, 127), 1: Interval(-7, 7)}


def _fx_index_map():
    """Off-by-one m-tile index map (i+1 instead of i) selects a block
    past the end of the operand -> index-map-bounds."""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    bm = _M // 2

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    fn = _pallas(kernel, ((_M, _K), jnp.int8), (2,),
                 [pl.BlockSpec((bm, _K), lambda i: (i + 1, 0))],
                 pl.BlockSpec((bm, _K), lambda i: (i, 0)))
    return fn, (_ints((_M, _K)),), {0: Interval(-127, 127)}


def _fx_divisibility():
    """Block shape that does not divide the operand (N=192 vs bn=128) ->
    blockspec-divisibility."""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    n, bn = 192, 128

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    fn = _pallas(kernel, ((_M, n), jnp.int8), (2,),
                 [pl.BlockSpec((_M, bn), lambda j: (0, j))],
                 pl.BlockSpec((_M, bn), lambda j: (0, j)))
    return fn, (_ints((_M, n)),), {0: Interval(-127, 127)}


def entries() -> list:
    """All broken fixtures; every one must produce >= 1 finding."""
    return [
        KernelEntry("broken-fp32-dot", "float dot on IS path",
                    _fx_fp32_dot, integer_scale=True, alpha=1024),
        KernelEntry("broken-no-preferred", "int dot w/o int32 accumulator",
                    _fx_no_preferred),
        KernelEntry("broken-narrowing", "int32 acc through int16",
                    _fx_narrowing),
        KernelEntry("broken-index-map", "m-tile index map off by one",
                    _fx_index_map),
        KernelEntry("broken-divisibility", "192 % 128 != 0",
                    _fx_divisibility),
    ]
