"""Overflow certificates for the Eq. 2 INT32 group accumulator (qlint).

The certificate contract
------------------------
A :class:`Certificate` states, for one (kernel, config):

    under the activation contract |x| <= qmax(a_bits) and the weight
    contract |w| <= dtype range of the quantized codes, with the GIVEN
    integer scales, the worst-case magnitude any integer value reaches
    in the accumulation chain is ``bound`` — and ``bound < 2**31``
    implies the kernel can NEVER overflow INT32, for any input.

The bound is derived by the interval interpreter (:mod:`.interp`) over a
*traced jaxpr* — either the actual Pallas kernel (registry path) or the
Eq. 2 reference contraction (per-layer path used at quantization time),
never from a hand-maintained formula.

Verdicts:

* ``certified``    — safe at the requested amplifier.
* ``capped-alpha`` — the requested amplifier could overflow; the largest
  safe alpha = 2^e (``resolved_alpha``) was substituted. This is the
  static replacement for trusting ``heuristic_amplifier`` alone.
* ``fallback``     — no power-of-two amplifier >= 1 is statically safe:
  the layer must take the paper's §B.4 de-amplified safe GEMM.

``finish_quant`` (core/qlinear.py) calls :func:`resolve_amplifier` for
every integer-scale layer and applies the verdict; every certificate is
appended to a module-level log (:func:`log`, :func:`summary`) so PTQ /
recipes / dry-runs can surface what was certified, capped, or demoted.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math

import numpy as np

from repro import obs

from .intervals import Interval

INT32_LIMIT = float(2**31)


def _qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


@dataclasses.dataclass(frozen=True)
class Certificate:
    kernel: str      # kernel fn / layer path this certifies
    config: str      # human-readable config (bits, group, K, ...)
    alpha: int       # requested amplifier
    resolved_alpha: int  # amplifier after capping (== alpha if certified)
    bound: float     # worst-case |integer accumulator| at resolved_alpha
    verdict: str     # "certified" | "capped-alpha" | "fallback"

    @property
    def ok(self) -> bool:
        """Gate semantics: capping is designed actuation, not a failure."""
        return self.verdict in ("certified", "capped-alpha")

    def __str__(self) -> str:
        extra = ""
        if self.verdict == "capped-alpha":
            extra = f" alpha {self.alpha}->{self.resolved_alpha}"
        return (f"[{self.verdict}] {self.kernel} ({self.config}) "
                f"bound={self.bound:.3g} "
                f"({self.bound / INT32_LIMIT:.3f} of 2^31){extra}")


# -- certificate log (consumed by ptq/recipe/dryrun summaries) --------------

_LOG: list[Certificate] = []
_CONTEXT: list[str] = []


@contextlib.contextmanager
def context(label: str):
    """Label certificates recorded inside (e.g. the PTQ layer path)."""
    _CONTEXT.append(label)
    try:
        yield
    finally:
        _CONTEXT.pop()


def record(cert: Certificate) -> Certificate:
    """Single chokepoint every certificate passes through — also the place
    the ``qcert_verdicts_total{verdict}`` telemetry counter ticks."""
    _LOG.append(cert)
    obs.current_registry().counter(
        "qcert_verdicts_total",
        "INT32-overflow certificates by verdict", ("verdict",),
    ).inc(verdict=cert.verdict)
    return cert


def log() -> list[Certificate]:
    return list(_LOG)


def clear_log() -> None:
    _LOG.clear()


def summary(certs: list[Certificate] | None = None) -> dict:
    """{"certified": n, "capped-alpha": n, "fallback": n, "worst_frac": f}"""
    certs = _LOG if certs is None else certs
    out = {"certified": 0, "capped-alpha": 0, "fallback": 0}
    worst = 0.0
    for c in certs:
        out[c.verdict] = out.get(c.verdict, 0) + 1
        worst = max(worst, c.bound / INT32_LIMIT)
    out["worst_frac"] = round(worst, 4)
    return out


# -- per-layer static bound (Eq. 2 reference contraction) -------------------


@functools.lru_cache(maxsize=None)
def _ref_gemm_jaxpr(G: int, gs: int, N: int):
    """Traced Eq. 2 int32 contraction: per-group int dot, int32
    scale-multiply, int32 sum over groups (shape-polymorphic via cache)."""
    import jax
    import jax.numpy as jnp

    def f(xq, w, int_scale):
        x3 = xq.reshape(-1, G, gs)
        w3 = w.reshape(G, gs, N)
        part = jax.lax.dot_general(
            x3, w3,
            dimension_numbers=(((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.int32,
        )  # (G, M, N)
        return jnp.sum(part * int_scale[:, None, :], axis=0)

    args = (jax.ShapeDtypeStruct((8, G * gs), jnp.int8),
            jax.ShapeDtypeStruct((G * gs, N), jnp.int8),
            jax.ShapeDtypeStruct((G, N), jnp.int32))
    return jax.make_jaxpr(f)(*args)


def static_accum_bound(int_scale, *, group_size: int, w_bits: int,
                       a_bits: int = 8) -> float:
    """Worst-case |int32 accumulator| for Eq. 2 with these integer scales.

    Seeds: activations from the a_bits contract, weight codes from the
    w_bits code range, scales tight from the concrete array; the bound is
    whatever the interval pass derives over the traced contraction. By
    construction it dominates ``integer_scale.empirical_max_accum`` on
    any input satisfying the contracts (tested in tests/test_qlint.py).
    """
    ints = np.asarray(int_scale)
    if ints.ndim != 2:
        raise ValueError(f"int_scale must be (G, N), got {ints.shape}")
    G, N = ints.shape
    from .interp import analyze_jaxpr

    closed = _ref_gemm_jaxpr(G, int(group_size), N)
    qa, qw = _qmax(a_bits), _qmax(w_bits)
    seeds = [Interval(-qa, qa), Interval(-qw, qw), Interval.of_array(ints)]
    return analyze_jaxpr(closed, seeds).int_accum_bound


def _int_scales_at(scales: np.ndarray, alpha: int) -> np.ndarray:
    """Mirror of integer_scale.integerize's rounding (numpy)."""
    return np.clip(np.round(scales.astype(np.float64) * alpha),
                   1, 2**31 - 1)


def resolve_amplifier(scales, *, alpha: int, group_size: int, w_bits: int,
                      a_bits: int = 8, kernel: str = "") -> Certificate:
    """Certify ``alpha`` for a layer's float scales — or cap it.

    Searches downward over power-of-two amplifiers for the largest
    statically safe one; the bound is monotone in max(int_scale), so the
    search costs at most two interval-analysis runs. Returns (and logs) a
    Certificate; callers apply ``resolved_alpha``.
    """
    s = np.asarray(scales, np.float32)
    if s.ndim == 1:
        s = s[:, None]
    kernel = kernel or "/".join(_CONTEXT) or "layer"
    e0 = int(round(math.log2(alpha)))
    cfg = (f"W{w_bits}A{a_bits} g{group_size} K={s.shape[0] * group_size} "
           f"alpha=2^{e0}")
    kw = dict(group_size=group_size, w_bits=w_bits, a_bits=a_bits)

    bound0 = static_accum_bound(_int_scales_at(s, alpha), **kw)
    if bound0 < INT32_LIMIT:
        return record(Certificate(kernel, cfg, alpha, alpha, bound0,
                                  "certified"))

    # bound scales with max(int_scale): derive the per-unit coefficient and
    # jump straight to the largest plausibly-safe exponent, then verify.
    smax = float(s.max())
    coeff = bound0 / max(float(_int_scales_at(s, alpha).max()), 1.0)
    for e in range(e0 - 1, -1, -1):
        max_int = max(1.0, float(np.round(smax * 2**e)))
        if coeff * max_int >= INT32_LIMIT:
            continue
        bound = static_accum_bound(_int_scales_at(s, 2**e), **kw)
        if bound < INT32_LIMIT:
            return record(Certificate(kernel, cfg, alpha, 2**e, bound,
                                      "capped-alpha"))
    return record(Certificate(kernel, cfg, alpha, alpha, bound0, "fallback"))


# -- registry-kernel certification (bound from the Pallas jaxpr itself) -----


def certify_analysis(name: str, config: str, analysis, *,
                     alpha) -> Certificate:
    """Certificate for an analyzed kernel trace: the bound is the interval
    pass's worst integer-arithmetic magnitude over the REAL kernel jaxpr
    (pallas body included), not the reference contraction."""
    bound = analysis.int_accum_bound
    a = int(alpha) if alpha else 1
    verdict = "certified" if bound < INT32_LIMIT else "fallback"
    return record(Certificate(name, config, a, a, bound, verdict))


# -- spec-level verdict (no tensors yet: dry-run / recipe summaries) --------

# Scale contract for data-free spec verdicts: fine-grained RTN group scales
# satisfy scale = group absmax / qmax, and every trained checkpoint in this
# repo (and the paper's LLaMA/Mistral families) sits well below
# absmax=0.35 per group => scale < 0.05 for W4. Quantization-time
# certificates (above) replace this assumption with the layer's real
# scales; the spec verdict only feeds dry-run summaries.
SCALE_CONTRACT = 0.05


def spec_verdict(spec, K: int) -> str:
    """Static verdict for a QuantSpec at contraction size K.

    Returns one of "certified" / "capped-alpha" / "fallback" for integer-
    scale specs (under the SCALE_CONTRACT assumption), "n/a" for float-
    scale / weight-only / coarse specs (no INT32 accumulation to certify),
    and "data-dependent" for heuristic amplifiers (resolved per layer at
    quantization time).
    """
    if spec is None or spec.weight_only or spec.scale_mode != "integer" \
            or not spec.fine_grained:
        return "n/a"
    if isinstance(spec.amplifier, str):
        return "data-dependent"
    if K % spec.group_size:
        return "n/a"
    G = K // spec.group_size
    scales = np.full((G, 1), SCALE_CONTRACT, np.float32)
    cert = resolve_amplifier(
        scales, alpha=int(spec.amplifier), group_size=spec.group_size,
        w_bits=spec.w_bits, a_bits=spec.a_bits,
        kernel=f"spec:{spec.name}@K={K}")
    return cert.verdict
