"""Kernel/config registry walked by ``python -m repro.analysis.qlint``.

Every Pallas kernel in ``repro.kernels`` is registered here with a
representative config: deterministic synthetic weights/scales (static
operands are seeded tight from their concrete values) and contract
ranges for the data-dependent operands (activations from the a_bits
range, ragged row counts from the wrapper's [0, C] clamp contract).

How to register a new kernel
----------------------------
Append a :class:`KernelEntry` in :func:`entries`:

* ``build`` returns ``(fn, args, input_ranges)`` — ``fn(*args)`` must be
  traceable by ``jax.make_jaxpr`` (the jitted wrappers are fine);
  ``input_ranges`` maps arg positions to :class:`Interval` contract
  ranges (or ``interp.DATA``) for operands whose concrete values are
  placeholders.
* set ``integer_scale=True`` (and ``alpha``) iff the kernel carries the
  Eq. 2 INT32 accumulation — it then gets an overflow certificate and
  the single-convert lint rule.
* ragged kernels set ``prefetch_ranges`` so the index-map bounds rule
  can seed the scalar-prefetch refs.

Shapes are kept small (tracing + interval interpretation run in CI on
every push) but structurally faithful: multiple k-steps (nk=2) so the
accumulator carry across the minor grid axis is analyzed, multiple
groups per block, packed int4 weights, padded+ragged expert slabs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import numpy as np

from .interp import DATA
from .intervals import Interval

# synthetic shapes — small but multi-tile in every dimension that matters
M, K, N, GS, BK = 8, 512, 256, 128, 256
E, C = 2, 64
G = K // GS
# engine decode shapes: the continuous-batching decode tick routes at most
# max_slots * top_k tokens, so per-expert capacity snaps to the 8-row floor
# — the grouped call the serving path issues every tick is certified at
# this small-M config (incl. a zero-routed expert in the rep row counts).
E_DEC, C_DEC = 4, 8


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    name: str
    config: str
    build: Callable[[], tuple]  # -> (fn, args, input_ranges)
    integer_scale: bool = False
    alpha: float | None = None
    a_bits: int = 8
    prefetch_ranges: tuple = ()
    meta: Any = None


def _codes(rng, k, n, bits):
    q = 2 ** (bits - 1) - 1
    return rng.integers(-q, q + 1, size=(k, n)).astype(np.int8)


def _packed(codes4):
    import jax.numpy as jnp

    from repro.core import packing

    return np.asarray(packing.pack_int4(jnp.asarray(codes4)))


def _w4_operands(rng, k=K, n=N, alpha=1024):
    packed = _packed(_codes(rng, k, n, 4))
    scales = rng.uniform(0.005, 0.02, (k // GS, n)).astype(np.float32)
    ints = np.clip(np.round(scales * alpha), 1, 2**31 - 1).astype(np.int32)
    return packed, scales, ints


def _w8_operands(rng, k=K, n=N):
    """W8 scales are ~18x smaller; amplifier follows the shipped
    heuristic+6 spec (recipe.W8A8_FG)."""
    import jax.numpy as jnp

    from repro.core import integer_scale as isc

    codes = _codes(rng, k, n, 8)
    scales = rng.uniform(8e-4, 1.2e-3, (k // GS, n)).astype(np.float32)
    exp = int(isc.heuristic_amplifier_exp(jnp.asarray(scales))) + 6
    alpha = int(2 ** min(exp, isc.MAX_AMPLIFIER_EXP))
    ints = np.clip(np.round(scales * alpha), 1, 2**31 - 1).astype(np.int32)
    return codes, scales, ints, alpha


def _sa(rng, *lead):
    return rng.uniform(1e-3, 0.05, (*lead, 1)).astype(np.float32)


def _j(x):
    import jax.numpy as jnp

    return jnp.asarray(x)


def _build_dense_is(w_bits: int, a_bits: int):
    def build():
        from repro.kernels import w4a8_gemm as W

        rng = np.random.default_rng(0)
        if w_bits == 4:
            wq, _, ints = _w4_operands(rng)
            alpha = 1024.0
        else:
            wq, _, ints, alpha = _w8_operands(rng)
        qa = 2 ** (a_bits - 1) - 1
        fn = functools.partial(
            W.fg_gemm_integer_scale, group_size=GS, alpha=float(alpha),
            w_bits=w_bits, bk=BK)
        args = (_j(np.zeros((M, K), np.int8)), _j(_sa(rng, M)),
                _j(wq), _j(ints))
        return fn, args, {0: Interval(-qa, qa)}
    return build


def _build_dense_fs(group_size: int):
    def build():
        from repro.kernels import w4a8_gemm_fscale as W

        rng = np.random.default_rng(1)
        wq, scales, _ = _w4_operands(rng)
        if group_size <= 0:
            scales = scales.max(axis=0, keepdims=True)  # (1, N) coarse
        fn = functools.partial(
            W.fg_gemm_float_scale, group_size=group_size, w_bits=4, bk=BK)
        args = (_j(np.zeros((M, K), np.int8)), _j(_sa(rng, M)),
                _j(wq), _j(scales))
        return fn, args, {0: Interval(-127, 127)}
    return build


def _build_w4a16():
    from repro.kernels import w4a16_gemm as W

    rng = np.random.default_rng(2)
    wq, scales, _ = _w4_operands(rng)
    fn = functools.partial(W.w4a16_gemm, group_size=GS, bk=BK)
    args = (_j(np.zeros((M, K), np.float32)), _j(wq), _j(scales))
    return fn, args, {0: DATA}


def _build_act_quant():
    from repro.kernels import act_quant as A

    fn = functools.partial(A.act_quant, bits=8)
    return fn, (_j(np.zeros((64, 256), np.float32)),), {0: DATA}


def _build_flash():
    from repro.kernels import flash_attention as F

    rng = np.random.default_rng(3)
    q = rng.normal(size=(1, 256, 2, 64)).astype(np.float32)
    k = rng.normal(size=(1, 256, 1, 64)).astype(np.float32)
    v = rng.normal(size=(1, 256, 1, 64)).astype(np.float32)
    fn = functools.partial(F.flash_attention_tpu, causal=True, bk=128)
    return fn, (_j(q), _j(k), _j(v)), {0: DATA, 1: DATA, 2: DATA}


def _moe_w4(rng, alpha=1024):
    packed, ints = [], []
    for _ in range(E):
        p, _, i = _w4_operands(rng, alpha=alpha)
        packed.append(p)
        ints.append(i)
    return np.stack(packed), np.stack(ints)


def _build_moe_dense(integer: bool):
    def build():
        from repro.kernels import moe_gemm as MG

        rng = np.random.default_rng(4)
        packed, ints = _moe_w4(rng)
        if integer:
            fn = functools.partial(
                MG.fg_grouped_gemm_integer_scale, group_size=GS,
                alpha=1024.0, w_bits=4, bk=BK)
            scale_arg = ints
        else:
            fn = functools.partial(
                MG.fg_grouped_gemm_float_scale, group_size=GS,
                w_bits=4, bk=BK)
            scale_arg = (ints / 1024.0).astype(np.float32)
        args = (_j(np.zeros((E, C, K), np.int8)), _j(_sa(rng, E, C)),
                _j(packed), _j(scale_arg))
        return fn, args, {0: Interval(-127, 127)}
    return build


def _build_moe_ragged(integer: bool):
    def build():
        from repro.kernels import moe_gemm as MG

        rng = np.random.default_rng(5)
        packed, ints = _moe_w4(rng)
        rc = np.asarray([37, C], np.int32)
        if integer:
            fn = functools.partial(
                MG.fg_grouped_gemm_integer_scale_ragged, group_size=GS,
                alpha=1024.0, a_bits=8, w_bits=4, bk=BK)
            scale_arg = ints
        else:
            fn = functools.partial(
                MG.fg_grouped_gemm_float_scale_ragged, group_size=GS,
                a_bits=8, w_bits=4, bk=BK)
            scale_arg = (ints / 1024.0).astype(np.float32)
        args = (_j(np.zeros((E, C, K), np.float32)), _j(rc),
                _j(packed), _j(scale_arg))
        return fn, args, {0: DATA, 1: Interval(0, C)}
    return build


def _build_moe_ragged_decode(integer: bool):
    def build():
        from repro.kernels import moe_gemm as MG

        rng = np.random.default_rng(7)
        packed, ints = [], []
        for _ in range(E_DEC):
            p, _, i = _w4_operands(rng)
            packed.append(p)
            ints.append(i)
        rc = np.asarray([0, 3, C_DEC, 5], np.int32)  # incl. idle expert
        if integer:
            fn = functools.partial(
                MG.fg_grouped_gemm_integer_scale_ragged, group_size=GS,
                alpha=1024.0, a_bits=8, w_bits=4, bk=BK)
            scale_arg = np.stack(ints)
        else:
            fn = functools.partial(
                MG.fg_grouped_gemm_float_scale_ragged, group_size=GS,
                a_bits=8, w_bits=4, bk=BK)
            scale_arg = (np.stack(ints) / 1024.0).astype(np.float32)
        args = (_j(np.zeros((E_DEC, C_DEC, K), np.float32)), _j(rc),
                _j(np.stack(packed)), _j(scale_arg))
        return fn, args, {0: DATA, 1: Interval(0, C_DEC)}
    return build


def _qspec_is():
    from repro.core.recipe import QuantSpec

    return QuantSpec(w_bits=4, a_bits=8, group_size=GS,
                     scale_mode="integer", amplifier=1024)


def _build_ops_dense():
    """The instrumented ``kernels.ops.qgemm`` wrapper end-to-end (telemetry
    is host-side python, so the traced jaxpr must stay identical to the
    bare act-quant + integer-scale kernel composition)."""
    from repro.kernels import ops

    rng = np.random.default_rng(8)
    wq, _, ints = _w4_operands(rng)
    params = {"qvalue": _j(wq), "scale": _j(ints), "alpha": 1024.0}
    spec = _qspec_is()

    def fn(x):
        return ops.qgemm(x, params, spec, block=ops.BlockConfig(bk=BK))

    return fn, (_j(np.zeros((M, K), np.float32)),), {0: DATA}


def _build_ops_grouped():
    """The instrumented ``kernels.ops.qgemm_grouped`` wrapper over the
    ragged fused-quant path (row_counts traced, as the engine feeds it)."""
    from repro.kernels import ops

    rng = np.random.default_rng(9)
    packed, ints = _moe_w4(rng)
    params = {"qvalue": _j(packed), "scale": _j(ints), "alpha": 1024.0}
    spec = _qspec_is()

    def fn(x, rc):
        return ops.qgemm_grouped(x, params, spec, row_counts=rc,
                                 block=ops.BlockConfig(bk=BK))

    args = (_j(np.zeros((E, C, K), np.float32)),
            _j(np.asarray([23, C], np.int32)))
    return fn, args, {0: DATA, 1: Interval(0, C)}


def _build_w4a16_ragged():
    from repro.kernels import moe_gemm as MG

    rng = np.random.default_rng(6)
    packed, scales = [], []
    for _ in range(E):
        p, s, _ = _w4_operands(rng)
        packed.append(p)
        scales.append(s)
    rc = np.asarray([17, C], np.int32)
    fn = functools.partial(MG.grouped_w4a16_gemm_ragged, group_size=GS,
                           bk=BK)
    args = (_j(np.zeros((E, C, K), np.float32)), _j(rc),
            _j(np.stack(packed)), _j(np.stack(scales)))
    return fn, args, {0: DATA, 1: Interval(0, C)}


_RC = (Interval(0.0, float(C)),)
_RC_DEC = (Interval(0.0, float(C_DEC)),)


def entries() -> list:
    """All shipped kernels x configs, in lint/certify order."""
    return [
        KernelEntry("w4a8-is", f"W4A8 g{GS} K={K} alpha=1024 bk={BK}",
                    _build_dense_is(4, 8), integer_scale=True, alpha=1024),
        KernelEntry("w8a8-is", f"W8A8 g{GS} K={K} alpha=heuristic+6",
                    _build_dense_is(8, 8), integer_scale=True),
        KernelEntry("w4a4-is", f"W4A4 g{GS} K={K} alpha=1024",
                    _build_dense_is(4, 4), integer_scale=True, alpha=1024,
                    a_bits=4),
        KernelEntry("w4a8-fs", f"W4A8 float-scale g{GS} K={K}",
                    _build_dense_fs(GS)),
        KernelEntry("w4a8-coarse", f"W4A8 per-channel K={K}",
                    _build_dense_fs(-1)),
        KernelEntry("w4a16", f"W4A16 weight-only g{GS} K={K}",
                    _build_w4a16),
        KernelEntry("act-quant", "per-token int8, M=64 K=256",
                    _build_act_quant),
        KernelEntry("flash-attention", "causal Sq=Sk=256 bq=256 bk=128",
                    _build_flash),
        KernelEntry("moe-w4a8-is", f"grouped E={E} C={C} K={K} alpha=1024",
                    _build_moe_dense(True), integer_scale=True, alpha=1024),
        KernelEntry("moe-w4a8-fs", f"grouped E={E} C={C} K={K} float-scale",
                    _build_moe_dense(False)),
        KernelEntry("moe-w4a8-is-ragged",
                    f"ragged fused-quant E={E} C={C} K={K} alpha=1024",
                    _build_moe_ragged(True), integer_scale=True, alpha=1024,
                    prefetch_ranges=_RC),
        KernelEntry("moe-w4a8-fs-ragged",
                    f"ragged fused-quant E={E} C={C} K={K} float-scale",
                    _build_moe_ragged(False), prefetch_ranges=_RC),
        KernelEntry("moe-w4a16-ragged",
                    f"ragged weight-only E={E} C={C} K={K}",
                    _build_w4a16_ragged, prefetch_ranges=_RC),
        KernelEntry("moe-w4a8-is-ragged-decode",
                    f"engine decode E={E_DEC} C={C_DEC} K={K} alpha=1024",
                    _build_moe_ragged_decode(True), integer_scale=True,
                    alpha=1024, prefetch_ranges=_RC_DEC),
        KernelEntry("moe-w4a8-fs-ragged-decode",
                    f"engine decode E={E_DEC} C={C_DEC} K={K} float-scale",
                    _build_moe_ragged_decode(False),
                    prefetch_ranges=_RC_DEC),
        # instrumented dispatch wrappers (telemetry must not perturb jaxprs)
        KernelEntry("ops-qgemm-is",
                    f"ops.qgemm W4A8-IS g{GS} K={K} alpha=1024",
                    _build_ops_dense, integer_scale=True, alpha=1024),
        KernelEntry("ops-qgemm-grouped-is",
                    f"ops.qgemm_grouped ragged E={E} C={C} K={K} alpha=1024",
                    _build_ops_grouped, integer_scale=True, alpha=1024,
                    prefetch_ranges=_RC),
    ]
