"""Lint rules over analyzed kernel traces (qlint pass 2).

Each rule is a function ``(entry, analysis) -> list[Finding]`` run over
the :class:`~repro.analysis.interp.Analysis` of one registered kernel
(:mod:`.registry`). The shipped rules:

``int-dot-preferred-type``
    Every integer-input ``dot_general`` must carry
    ``preferred_element_type=jnp.int32`` — without it XLA accumulates the
    MXU partials in the operand dtype (int8!) and saturates silently.
``narrowing-convert``
    An integer->integer ``convert_element_type`` whose statically derived
    value interval does not fit the target dtype (interval-aware: the int4
    nibble unpack's int32->int8 with derived range [-8, 7] is clean).
``int-overflow``
    Integer add/mul/dot/reduce whose interval escapes its result dtype —
    the direct "accumulation can overflow before it completes" signal.
``float-accum-on-is-path``
    On kernels registered as integer-scale (Eq. 2): any float-input
    ``dot_general`` in the kernel body, or more than ONE distinct
    int->float convert (the single-final-convert property IS the paper's
    speedup; per-group converts mean the Eq. 1 bottleneck crept back in).
``blockspec-divisibility``
    Block shapes must divide the (padded) operand arrays — a mismatch
    means silent partial tiles diverging from the TPU path.
``index-map-bounds``
    Interval-evaluates every BlockSpec index map over the whole grid
    (ragged scalar-prefetch row-count refs seeded from the wrapper's
    documented [0, C] clamp contract); block indices must stay within the
    operand's tile range.
``uninit-read``
    A kernel body read of an output/scratch ref no grid step has written.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .interp import Analysis, analyze_index_map
from .intervals import Interval


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    kernel: str
    message: str
    where: str = ""

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.kernel}: {self.rule}: {self.message}{loc}"


def _is_int(dtype_str: str) -> bool:
    try:
        return np.dtype(dtype_str).kind in "iu"
    except TypeError:
        return False


def rule_int_dot_preferred(entry, an: Analysis) -> list:
    out, seen = [], set()
    for r in an.records:
        if r.prim != "dot_general" or r.eqn_id in seen:
            continue
        seen.add(r.eqn_id)
        if not all(_is_int(d) for d in r.in_dtypes):
            continue
        pet = r.params.get("preferred_element_type")
        if pet is None or np.dtype(pet) != np.dtype(np.int32):
            out.append(Finding(
                "int-dot-preferred-type", entry.name,
                f"integer dot_general accumulates in "
                f"{pet or r.out_dtype}, not int32", r.where))
    return out


def rule_events(entry, an: Analysis) -> list:
    """narrowing-convert / int-overflow / uninit-read events -> findings."""
    out, seen = [], set()
    for e in an.events:
        if e.kind not in ("narrowing-convert", "int-overflow", "uninit-read"):
            continue
        key = (e.kind, e.prim, e.where, e.detail)
        if key in seen:
            continue
        seen.add(key)
        out.append(Finding(e.kind, entry.name, e.detail, e.where))
    return out


def rule_float_accum_on_is_path(entry, an: Analysis) -> list:
    if not getattr(entry, "integer_scale", False):
        return []
    out, seen = [], set()
    n_converts = set()
    for r in an.records:
        if not r.scope.startswith("pallas"):
            continue
        if r.prim == "dot_general" and r.eqn_id not in seen:
            seen.add(r.eqn_id)
            if not all(_is_int(d) for d in r.in_dtypes):
                out.append(Finding(
                    "float-accum-on-is-path", entry.name,
                    "float dot_general inside an integer-scale kernel "
                    "body (Eq. 2 requires the int8 MXU path)", r.where))
        if (r.prim == "convert_element_type" and r.in_dtypes
                and _is_int(r.in_dtypes[0])
                and np.dtype(r.in_dtypes[0]).itemsize >= 4
                and not _is_int(r.out_dtype)):
            n_converts.add(r.eqn_id)
    if len(n_converts) > 1:
        out.append(Finding(
            "float-accum-on-is-path", entry.name,
            f"{len(n_converts)} distinct int->float converts in the kernel "
            "body; Eq. 2 allows ONE (the epilogue) — per-group converts "
            "are the Eq. 1 bottleneck"))
    return out


def _block_dims(bm) -> list:
    dims = []
    for b in getattr(bm, "block_shape", ()) or ():
        try:
            dims.append(int(b))
        except (TypeError, ValueError):
            dims.append(1)  # mapped/squeezed dim
    return dims


def rule_blockspec_divisibility(entry, an: Analysis) -> list:
    out = []
    for p in an.pallas:
        for i, bm in enumerate(getattr(p.grid_mapping, "block_mappings", ())):
            shape = getattr(getattr(bm, "array_shape_dtype", None),
                            "shape", None)
            if shape is None:
                continue
            for d, (s, b) in enumerate(zip(shape, _block_dims(bm))):
                if b and s % b:
                    out.append(Finding(
                        "blockspec-divisibility", entry.name,
                        f"{p.name} operand {i} dim {d}: array extent {s} "
                        f"not divisible by block {b}"))
    return out


def rule_index_map_bounds(entry, an: Analysis) -> list:
    out = []
    prefetch = list(getattr(entry, "prefetch_ranges", ()) or ())
    for p in an.pallas:
        for i, bm in enumerate(getattr(p.grid_mapping, "block_mappings", ())):
            imj = getattr(bm, "index_map_jaxpr", None)
            shape = getattr(getattr(bm, "array_shape_dtype", None),
                            "shape", None)
            if imj is None or shape is None:
                continue
            blocks = _block_dims(bm)
            try:
                idx = analyze_index_map(imj, p.grid, prefetch, len(p.grid))
            except Exception as e:  # analysis gap, surface rather than hide
                out.append(Finding(
                    "index-map-bounds", entry.name,
                    f"{p.name} operand {i}: index map not analyzable "
                    f"({type(e).__name__}: {e})"))
                continue
            for d, iv in enumerate(idx):
                if d >= len(shape) or not isinstance(iv, Interval):
                    continue
                b = blocks[d] if d < len(blocks) and blocks[d] else 1
                hi = -(-shape[d] // b) - 1  # cdiv - 1
                if not iv.within(0, hi):
                    out.append(Finding(
                        "index-map-bounds", entry.name,
                        f"{p.name} operand {i} dim {d}: block index "
                        f"{iv} escapes [0, {hi}]"))
    return out


RULES = (
    rule_int_dot_preferred,
    rule_events,
    rule_float_accum_on_is_path,
    rule_blockspec_divisibility,
    rule_index_map_bounds,
)


def run_rules(entry, analysis: Analysis) -> list:
    out = []
    for rule in RULES:
        out.extend(rule(entry, analysis))
    return out
