"""qlint CLI — lint + overflow-certify every registered kernel.

Usage (CI gate)::

    PYTHONPATH=src python -m repro.analysis.qlint            # registry
    PYTHONPATH=src python -m repro.analysis.qlint --fixtures # must fail

Exit status is nonzero iff any lint finding fires or any integer-scale
kernel's certificate is not ``ok`` (certified / capped-alpha). Unknown
primitives are printed as warnings — they widen the analysis but are not
gate failures.
"""
from __future__ import annotations

import argparse
import sys

from . import certify, fixtures, registry
from .interp import analyze_fn
from .lint import run_rules


def analyze_entry(entry):
    fn, args, input_ranges = entry.build()
    return analyze_fn(fn, *args, input_ranges=input_ranges)


def check_entry(entry):
    """-> (findings, certificate | None, analysis)."""
    an = analyze_entry(entry)
    findings = run_rules(entry, an)
    cert = None
    if entry.integer_scale:
        cert = certify.certify_analysis(
            entry.name, entry.config, an, alpha=entry.alpha or 1)
    return findings, cert, an


def run_entries(entries, out=sys.stdout):
    """Check every entry, print one line each; -> (findings, certs)."""
    all_findings, certs = [], []
    for entry in entries:
        try:
            findings, cert, an = check_entry(entry)
        except Exception as e:
            from .lint import Finding

            findings, cert, an = [Finding(
                "analysis-error", entry.name,
                f"{type(e).__name__}: {e}")], None, None
        all_findings.extend(findings)
        if cert is not None:
            certs.append(cert)
        status = "ok " if not findings and (cert is None or cert.ok) \
            else "FAIL"
        tail = ""
        if cert is not None:
            tail = (f" bound={cert.bound:.3g}"
                    f" ({cert.bound / certify.INT32_LIMIT:.3f} of 2^31)"
                    f" [{cert.verdict}]")
        print(f"{status} {entry.name:24s} {entry.config}{tail}", file=out)
        for f in findings:
            print(f"     - {f}", file=out)
        if an is not None:
            for e in an.events_of("unknown-prim"):
                print(f"     ~ warn: {e.prim}: {e.detail}", file=out)
    return all_findings, certs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.qlint", description=__doc__)
    ap.add_argument("--fixtures", action="store_true",
                    help="run only the deliberately broken fixtures "
                         "(exit nonzero expected)")
    ap.add_argument("-k", "--filter", default="",
                    help="substring filter on kernel names")
    ns = ap.parse_args(argv)

    entries = fixtures.entries() if ns.fixtures else registry.entries()
    if ns.filter:
        entries = [e for e in entries if ns.filter in e.name]
    if not entries:
        print("qlint: no entries matched", file=sys.stderr)
        return 2

    findings, certs = run_entries(entries)
    bad_certs = [c for c in certs if not c.ok]
    n = len(findings) + len(bad_certs)
    s = certify.summary(certs)
    print(f"qlint: {len(entries)} kernels, {len(findings)} findings, "
          f"{s['certified']} certified / {s['capped-alpha']} capped / "
          f"{s['fallback']} fallback, worst accumulator "
          f"{s['worst_frac']:.3f} of 2^31")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
