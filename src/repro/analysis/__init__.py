"""repro.analysis — static certification + lint for the quantized stack.

Two cooperating passes over *traced jaxprs* (nothing here executes a
kernel):

1. **Interval dataflow** (:mod:`.intervals`, :mod:`.interp`) — seeds
   value ranges from quantized dtypes and config contracts (|xq| <=
   qmax(a_bits), weight codes from w_bits, integer scales tight from the
   concrete array), propagates them through dot_general / add / mul /
   convert / shifts / clamps and straight through ``pallas_call`` bodies
   (the innermost grid axis is iterated exactly, so the k-loop INT32
   accumulator is modeled without widening).

2. **Lint rules** (:mod:`.lint`) + **overflow certificates**
   (:mod:`.certify`) consuming the analysis:

   * certificate contract: ``bound < 2**31`` proves the Eq. 2 group
     accumulator can never overflow INT32 under the dtype contracts —
     verdicts ``certified`` / ``capped-alpha`` (largest safe power-of-two
     amplifier substituted) / ``fallback`` (take the paper's §B.4 safe
     GEMM). ``core.qlinear.finish_quant`` applies this to every
     integer-scale layer at quantization time.
   * lint rules: int-dot-preferred-type, narrowing-convert, int-overflow,
     float-accum-on-is-path, blockspec-divisibility, index-map-bounds,
     uninit-read (details in :mod:`.lint`).

To register a kernel, append a ``KernelEntry`` in
:mod:`.registry` (docstring there has the field contract). The CI gate
is ``python -m repro.analysis.qlint`` (:mod:`.qlint`).
"""
from .certify import (Certificate, certify_analysis, resolve_amplifier,
                      spec_verdict, static_accum_bound, summary)
from .interp import DATA, Analysis, analyze_fn, analyze_jaxpr
from .intervals import Interval
from .lint import Finding, run_rules
from .registry import KernelEntry, entries

__all__ = [
    "Analysis", "Certificate", "DATA", "Finding", "Interval",
    "KernelEntry", "analyze_fn", "analyze_jaxpr", "certify_analysis",
    "entries", "resolve_amplifier", "run_rules", "spec_verdict",
    "static_accum_bound", "summary",
]
