"""Interval (value-range) domain for the jaxpr dataflow analyzer.

One :class:`Interval` abstracts the element-wise value range of a whole
array — the analysis deliberately collapses tensor structure (per-group,
per-channel) into a single ``[lo, hi]`` so every transfer function is a
few scalar ops and soundness is easy to audit: whatever any element of
the concrete array can be, it lies inside the interval.

Bounds are python floats (ints promote losslessly up to 2**53; beyond
that float rounding only ever *widens* toward +/-inf, which stays sound
for overflow certification). ``+/-inf`` are legal bounds.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

INT_RANGES = {
    "int8": (-(2**7), 2**7 - 1),
    "int16": (-(2**15), 2**15 - 1),
    "int32": (-(2**31), 2**31 - 1),
    "int64": (-(2**63), 2**63 - 1),
    "uint8": (0, 2**8 - 1),
    "uint16": (0, 2**16 - 1),
    "uint32": (0, 2**32 - 1),
    "uint64": (0, 2**64 - 1),
    "bool": (0, 1),
}


def _mul(a: float, b: float) -> float:
    """Corner product with the interval convention 0 * inf = 0."""
    if a == 0 or b == 0:
        return 0.0
    return a * b


def _div(a: float, b: float) -> float:
    """Corner quotient; indeterminate inf/inf widens to +/-inf (sound)."""
    if a == 0:
        return 0.0
    if math.isinf(a) and math.isinf(b):
        return math.copysign(math.inf, a) * math.copysign(1.0, b)
    return a / b


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: float
    hi: float

    def __post_init__(self):
        # indeterminate corner arithmetic (inf - inf, ...) widens, not errors
        if math.isnan(self.lo):
            object.__setattr__(self, "lo", -math.inf)
        if math.isnan(self.hi):
            object.__setattr__(self, "hi", math.inf)
        assert not (self.lo > self.hi), f"bad interval [{self.lo}, {self.hi}]"

    # -- constructors -------------------------------------------------------

    @staticmethod
    def point(v) -> "Interval":
        v = float(v)
        return Interval(v, v)

    @staticmethod
    def top() -> "Interval":
        return Interval(-math.inf, math.inf)

    @staticmethod
    def from_dtype(dtype) -> "Interval":
        name = np.dtype(dtype).name
        if name in INT_RANGES:
            lo, hi = INT_RANGES[name]
            return Interval(float(lo), float(hi))
        return Interval.top()  # floats: unconstrained

    @staticmethod
    def of_array(x) -> "Interval":
        """Tight interval of a concrete array's values."""
        a = np.asarray(x)
        if a.size == 0:
            return Interval.point(0.0)
        return Interval(float(a.min()), float(a.max()))

    # -- predicates ---------------------------------------------------------

    def is_point(self) -> bool:
        return self.lo == self.hi and math.isfinite(self.lo)

    def within(self, lo: float, hi: float) -> bool:
        return self.lo >= lo and self.hi <= hi

    def fits_dtype(self, dtype) -> bool:
        name = np.dtype(dtype).name
        if name not in INT_RANGES:
            return True
        lo, hi = INT_RANGES[name]
        return self.within(lo, hi)

    def max_abs(self) -> float:
        return max(abs(self.lo), abs(self.hi))

    # -- lattice ------------------------------------------------------------

    def union(self, o: "Interval") -> "Interval":
        return Interval(min(self.lo, o.lo), max(self.hi, o.hi))

    # -- arithmetic transfer functions -------------------------------------

    def __add__(self, o: "Interval") -> "Interval":
        return Interval(self.lo + o.lo, self.hi + o.hi)

    def __sub__(self, o: "Interval") -> "Interval":
        return Interval(self.lo - o.hi, self.hi - o.lo)

    def __neg__(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def __mul__(self, o: "Interval") -> "Interval":
        cs = (_mul(self.lo, o.lo), _mul(self.lo, o.hi),
              _mul(self.hi, o.lo), _mul(self.hi, o.hi))
        return Interval(min(cs), max(cs))

    def truediv(self, o: "Interval") -> "Interval":
        if o.lo <= 0 <= o.hi:  # denominator may cross zero
            return Interval.top()
        cs = (_div(self.lo, o.lo), _div(self.lo, o.hi),
              _div(self.hi, o.lo), _div(self.hi, o.hi))
        return Interval(min(cs), max(cs))

    def intdiv(self, o: "Interval") -> "Interval":
        """XLA integer division truncates toward zero."""
        if o.lo <= 0 <= o.hi:
            return Interval.top()

        def t(a, b):
            if not (math.isfinite(a) and math.isfinite(b)):
                return _div(a, b)
            return float(math.trunc(a / b))

        cs = (t(self.lo, o.lo), t(self.lo, o.hi),
              t(self.hi, o.lo), t(self.hi, o.hi))
        return Interval(min(cs), max(cs))

    def floordiv(self, o: "Interval") -> "Interval":
        """Python/jnp floor division (rounds toward -inf)."""
        if o.lo <= 0 <= o.hi:
            return Interval.top()

        def t(a, b):
            if not (math.isfinite(a) and math.isfinite(b)):
                return _div(a, b)
            return float(math.floor(a / b))

        cs = (t(self.lo, o.lo), t(self.lo, o.hi),
              t(self.hi, o.lo), t(self.hi, o.hi))
        return Interval(min(cs), max(cs))

    def sum_n(self, n: int) -> "Interval":
        """Sum of n elements each drawn from this interval."""
        return Interval(_mul(float(n), self.lo), _mul(float(n), self.hi))

    def abs(self) -> "Interval":
        if self.lo >= 0:
            return self
        if self.hi <= 0:
            return -self
        return Interval(0.0, self.max_abs())

    def maximum(self, o: "Interval") -> "Interval":
        return Interval(max(self.lo, o.lo), max(self.hi, o.hi))

    def minimum(self, o: "Interval") -> "Interval":
        return Interval(min(self.lo, o.lo), min(self.hi, o.hi))

    def clamp(self, lo: "Interval", hi: "Interval") -> "Interval":
        """lax.clamp(lo, x, hi) = min(max(x, lo), hi)."""
        return self.maximum(lo).minimum(hi)

    def monotone(self, f) -> "Interval":
        """Apply a monotone-nondecreasing scalar map to both ends."""
        return Interval(f(self.lo), f(self.hi))

    def shift_right(self, n: "Interval") -> "Interval":
        """Arithmetic right shift: floor division by 2**n."""
        if not n.is_point():
            shifts = [int(n.lo), int(n.hi)]
        else:
            shifts = [int(n.lo)]
        los, his = [], []
        for s in shifts:
            d = float(2 ** max(s, 0))
            los.append(math.floor(self.lo / d)
                       if math.isfinite(self.lo) else self.lo)
            his.append(math.floor(self.hi / d)
                       if math.isfinite(self.hi) else self.hi)
        return Interval(min(los), max(his))

    def __repr__(self) -> str:  # compact for findings/certificates
        def f(v):
            if v == int(v) and abs(v) < 2**63 and math.isfinite(v):
                return str(int(v))
            return f"{v:.3g}"
        return f"[{f(self.lo)}, {f(self.hi)}]"
