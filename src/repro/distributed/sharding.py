"""Logical-axis -> mesh-axis sharding rules per execution mode.

One rule table per mode; :func:`repro.nn.spec.partition_specs` applies them
with divisibility checks (a mapping that doesn't divide the dim is dropped
to replication — this is what lets granite's kv=1 MQA and minicpm3's odd
vocab coexist with a 16-way model axis).

Axes vocabulary (see models/*):
  embed, mlp, mlp2, moe_mlp, heads_q, heads_kv, q_lora, kv_lora, vocab,
  experts, layers, cache_batch, cache_seq, batch, seq
"""
from __future__ import annotations

from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.nn import spec as S

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Training: FSDP over data (weights sharded on embed/mlp-in), TP over model
# (heads / ffn-out), EP over model for experts. `pod` composes as outer DP.


def train_rules(multi_pod: bool) -> S.Rules:
    data = ("pod", "data") if multi_pod else ("data",)
    return (
        ("embed", data),
        ("mlp", "model"),
        ("mlp2", None),
        ("moe_mlp", "model"),
        ("heads_q", "model"),
        ("heads_kv", "model"),
        ("q_lora", None),
        ("kv_lora", None),
        ("vocab", "model"),
        ("experts", "model"),
        ("layers", None),
        ("cache_batch", data),
        ("cache_seq", "model"),
    )


# Serving: weights TP-only on model (replicated across data rows so each
# row serves its batch slice with no weight collectives); batch over
# (pod,)data; KV sequence over model (flash-decoding style partial softmax).


def serve_rules(multi_pod: bool) -> S.Rules:
    data = ("pod", "data") if multi_pod else ("data",)
    return (
        ("embed", None),
        ("mlp", "model"),
        ("mlp2", None),
        ("moe_mlp", None),
        ("heads_q", "model"),
        ("heads_kv", "model"),
        ("q_lora", None),
        ("kv_lora", None),
        ("vocab", "model"),
        ("experts", "model"),
        ("layers", None),
        ("cache_batch", data),
        ("cache_seq", "model"),
    )


def rules_for(mode: str, multi_pod: bool = False) -> S.Rules:
    return train_rules(multi_pod) if mode == "train" else serve_rules(multi_pod)


# ---------------------------------------------------------------------------
# Activation / input shardings
# ---------------------------------------------------------------------------


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def input_shardings(mesh: Mesh, inputs: dict, multi_pod: bool = False,
                    divisible: bool = True) -> dict:
    """tokens/labels: shard batch over (pod,)data; stubs likewise."""
    b = batch_axes(multi_pod)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bsz = (sizes.get("pod", 1) * sizes["data"]) if multi_pod else sizes["data"]

    def one(v):
        if v.shape and v.shape[0] % bsz == 0:
            return NamedSharding(mesh, P(b, *([None] * (len(v.shape) - 1))))
        return NamedSharding(mesh, P())

    return {k: one(v) for k, v in inputs.items()}


def named_tree(mesh: Mesh, spec_tree, rules: S.Rules):
    return S.named_shardings(spec_tree, rules, mesh)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
