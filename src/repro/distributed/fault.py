"""Fault-tolerance utilities: heartbeats, straggler detection, restart drill.

On a real cluster these hooks feed a supervisor (k8s / Borg-style) that
reschedules slow or dead hosts; checkpoint+elastic-restore (see
repro.checkpoint.manager) closes the loop. Everything here is
dependency-free so it runs identically in tests.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable


@dataclasses.dataclass
class HeartbeatConfig:
    straggler_factor: float = 3.0   # step slower than 3x median => straggler
    window: int = 32                # median window
    deadline_s: float = 600.0       # hard per-step deadline


class Heartbeat:
    """Wraps the train loop's step boundary; detects stragglers."""

    def __init__(self, cfg: HeartbeatConfig | None = None,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.cfg = cfg or HeartbeatConfig()
        self.times: list[float] = []
        self.straggler_steps: list[int] = []
        self._t0: float | None = None
        self._on_straggler = on_straggler

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        self.times.append(dt)
        window = self.times[-self.cfg.window:]
        if len(window) >= 5:
            med = statistics.median(window)
            if dt > self.cfg.straggler_factor * med or dt > self.cfg.deadline_s:
                self.straggler_steps.append(step)
                if self._on_straggler:
                    self._on_straggler(step, dt, med)
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class FailureInjector:
    """Deterministic failure injection for restart drills (tests/examples):
    raises at a configured step, exactly once."""

    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")
