"""Fault-tolerance utilities: heartbeats, straggler detection, restart drill.

On a real cluster these hooks feed a supervisor (k8s / Borg-style) that
reschedules slow or dead hosts; checkpoint+elastic-restore (see
repro.checkpoint.manager) closes the loop. The serving engine reuses
:class:`Heartbeat` as its per-tick watchdog and ``repro.serving.chaos``
builds its deterministic fault schedules on :class:`FailureInjector`.
Everything here is dependency-free so it runs identically in tests.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Mapping


@dataclasses.dataclass
class HeartbeatConfig:
    straggler_factor: float = 3.0   # step slower than 3x median => straggler
    window: int = 32                # median window
    deadline_s: float = 600.0       # hard per-step deadline


class Heartbeat:
    """Wraps the train/serve loop's step boundary; detects stragglers.

    ``clock`` is the monotonic time source (``time.monotonic`` by
    default) — injectable so the serving engine can run it off the
    telemetry registry clock and tests can drive it deterministically.
    """

    def __init__(self, cfg: HeartbeatConfig | None = None,
                 on_straggler: Callable[[int, float, float], None] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or HeartbeatConfig()
        self.times: list[float] = []
        self.straggler_steps: list[int] = []
        self._t0: float | None = None
        self._on_straggler = on_straggler
        self._clock = clock

    def start(self) -> None:
        self._t0 = self._clock()

    def stop(self, step: int) -> float:
        # an unmatched stop used to fall back to ``now`` and record a ~0s
        # sample, silently dragging the straggler median toward zero —
        # refuse instead of corrupting the window
        if self._t0 is None:
            raise RuntimeError(
                "Heartbeat.stop() without a matching start(): refusing "
                "to record a bogus ~0s sample into the straggler median")
        dt = self._clock() - self._t0
        self._t0 = None
        self.times.append(dt)
        window = self.times[-self.cfg.window:]
        if len(window) >= 5:
            med = statistics.median(window)
            if dt > self.cfg.straggler_factor * med or dt > self.cfg.deadline_s:
                self.straggler_steps.append(step)
                if self._on_straggler:
                    self._on_straggler(step, dt, med)
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class FailureInjector:
    """Deterministic failure injection for restart drills and the serving
    chaos harness.

    Legacy form — ``FailureInjector(fail_at_step=3)`` — raises exactly
    once at the configured step. The generalized ``schedule`` maps a step
    to how many calls at that step should raise (serving retries re-enter
    the same step, so per-step counts express "fail the first N
    attempts"); ``exc_factory(step)`` builds the raised exception.
    ``fired_at`` logs every injection for test assertions.
    """

    def __init__(self, fail_at_step: int | None = None, *,
                 schedule: Mapping[int, int] | None = None,
                 exc_factory: Callable[[int], Exception] | None = None):
        self.fail_at_step = fail_at_step
        merged = dict(schedule or {})
        if fail_at_step is not None:
            merged[fail_at_step] = merged.get(fail_at_step, 0) + 1
        self.schedule = merged
        self._remaining = dict(merged)
        self.fired = False
        self.fired_at: list[int] = []
        self._exc = exc_factory or (
            lambda step: RuntimeError(f"injected node failure at step {step}"))

    def maybe_fail(self, step: int) -> None:
        if self._remaining.get(step, 0) > 0:
            self._remaining[step] -= 1
            self.fired = True
            self.fired_at.append(step)
            raise self._exc(step)
