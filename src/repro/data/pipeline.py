"""Deterministic synthetic-corpus data pipeline (offline container: no real
datasets). Produces a learnable token stream so the quickstart model's loss
actually falls and the quantization benchmarks have a meaningful perplexity.

Generator: a fixed random 2nd-order Markov chain over the vocab with Zipfian
marginals + periodic copy motifs — enough structure that an LM beats the
unigram entropy by a wide margin, fully reproducible from (seed, step,
shard), so restarts/stragglers replay identical batches (fault tolerance:
the pipeline is stateless-resumable).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 256
    batch_size: int = 32
    seed: int = 1234
    num_shards: int = 1  # data-parallel shards
    motif_period: int = 64


class SyntheticPipeline:
    """Stateless: batch(step, shard) is a pure function of the config."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # Zipfian unigram
        ranks = np.arange(1, V + 1)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # sparse bigram transitions: each token has ~8 likely successors
        succ = rng.integers(0, V, size=(V, 8))
        self._succ = succ
        # copy motif: fixed template inserted periodically
        self._motif = rng.integers(0, V, size=16)

    def _gen_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cfg = self.cfg
        V = cfg.vocab_size
        out = np.empty(n, np.int32)
        cur = int(rng.choice(V, p=self._unigram))
        for i in range(n):
            if i % cfg.motif_period < len(self._motif):
                out[i] = self._motif[i % cfg.motif_period]
                cur = int(out[i])
                continue
            if rng.random() < 0.8:  # follow the chain
                cur = int(self._succ[cur, rng.integers(0, 8)])
            else:  # resample from unigram
                cur = int(rng.choice(V, p=self._unigram))
            out[i] = cur
        return out

    def batch(self, step: int, shard: int = 0) -> dict[str, np.ndarray]:
        cfg = self.cfg
        per_shard = cfg.batch_size // cfg.num_shards
        rng = np.random.default_rng(
            (cfg.seed, step, shard))  # deterministic per (step, shard)
        toks = np.stack([
            self._gen_tokens(rng, cfg.seq_len + 1) for _ in range(per_shard)
        ])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        shards = [self.batch(step, s) for s in range(self.cfg.num_shards)]
        return {k: np.concatenate([s[k] for s in shards], 0)
                for k in shards[0]}

    def unigram_entropy(self) -> float:
        p = self._unigram
        return float(-(p * np.log(p)).sum())
