import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import (jax locks the
# device count on first init). The 512 placeholder host devices exist ONLY
# for this dry-run; tests/benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape decode_32k --multi-pod both --out results.jsonl

Training cells lower ``train_step`` (fp bf16 + AdamW); prefill/decode cells
lower the quantized serving step (paper W4A8 Integer Scale recipe) — that
is the deployment the paper targets. Failures here are bugs in the
framework's sharding; the roofline analysis (benchmarks/roofline.py) reads
the JSONL this writes.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, input_specs, shape_applicable
from repro.core.recipe import DEFAULT_RECIPE
from repro.distributed import sharding as shard
from repro.launch.mesh import axis_sizes, make_production_mesh
from repro.models.registry import get_arch, get_model, list_archs
from repro.nn import spec as S
from repro.training import optimizer as O
from repro.training.train_step import make_train_step

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def normalized_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on current jax but a
    list of per-program dicts on older versions — normalize to one dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s4": 0.5, "u4": 0.5}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective buffer bytes from post-SPMD HLO, with a
    wire-traffic estimate per op semantics (ring algorithms)."""
    out = {c: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
           for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", ls)
        if m:
            ls = m.group(1)
        kind = None
        for c in COLLECTIVES:
            if re.match(rf"(\([^)]*\)|\S+)?\s*{c}[-\w]*\(", ls) or \
                    ls.startswith(c):
                kind = c
                break
        if kind is None:
            continue
        # result shape(s): leading "dt[dims]" or tuple "(dt[..], dt[..])"
        shapes = _SHAPE_RE.findall(ls.split(f"{kind}")[0])
        nbytes = 0.0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        # group size for wire factor
        gsz = 1
        gm = _GROUPS_RE.search(ls)
        if gm:
            gsz = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(ls)
            if gb:
                gsz = len(gb.group(1).split(","))
        f = (gsz - 1) / max(gsz, 1)
        wire = {"all-reduce": 2 * f * nbytes,
                "all-gather": f * nbytes,
                "reduce-scatter": (gsz - 1) * nbytes,
                "all-to-all": f * nbytes,
                "collective-permute": 1.0 * nbytes}[kind]
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
        out[kind]["wire_bytes"] += wire
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_wire_bytes"] = sum(v["wire_bytes"] for v in out.values()
                                  if isinstance(v, dict))
    return out


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               cfg_overrides: dict | None = None, rules=None,
               token_sharding=None):
    """Returns (lower_fn, meta) — lower_fn() does the actual lowering.

    cfg_overrides / rules / token_sharding support the §Perf hillclimb
    variants (e.g. int8 KV, int8 MoE dispatch, replicated-weight serving).
    """
    cfg = get_arch(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shp = SHAPES[shape_name]
    sizes = axis_sizes(mesh)
    data_ways = sizes.get("pod", 1) * sizes["data"]
    if cfg.num_experts:
        g = data_ways if (shp.batch * (shp.seq if shp.kind == "train" else 1)
                          ) % data_ways == 0 else 1
        cfg = dataclasses.replace(cfg, dispatch_groups=g)
    api = get_model(cfg)
    mode = shp.kind
    recipe = None if mode == "train" else DEFAULT_RECIPE
    if rules is None:
        rules = shard.rules_for(mode, multi_pod)
    pspecs = api.param_specs(cfg, recipe)
    pshard = shard.named_tree(mesh, pspecs, rules)
    inputs = input_specs(cfg, shp)
    ishard = shard.input_shardings(mesh, inputs, multi_pod)
    if token_sharding is not None:
        from jax.sharding import NamedSharding

        ishard = dict(ishard)
        ishard["tokens"] = NamedSharding(mesh, token_sharding)
    mem_key = ("image_embeds" if "image_embeds" in inputs
               else "frames" if "frames" in inputs else None)

    if mode == "train":
        ospecs = O.state_specs(pspecs)
        oshard = shard.named_tree(mesh, ospecs, rules)
        step = make_train_step(api, cfg, O.AdamWConfig())

        def lower():
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, ishard),
                out_shardings=(pshard, oshard, None),
            )
            return jitted.lower(S.abstract(pspecs), S.abstract(ospecs),
                                {k: v for k, v in inputs.items()})

        return lower, cfg

    cspecs = api.cache_specs(cfg, shp.batch, shp.seq)
    cshard = shard.named_tree(mesh, cspecs, rules)

    if mode == "prefill":
        def prefill_step(params, cache, inp):
            logits, cache, _ = api.apply(
                params, cfg, inp["tokens"], recipe=recipe, mode="prefill",
                cache=cache, pos=0,
                memory=inp.get(mem_key) if mem_key else None)
            return logits[:, -1], cache

        def lower():
            jitted = jax.jit(
                prefill_step,
                in_shardings=(pshard, cshard, ishard),
                out_shardings=(None, cshard),
            )
            return jitted.lower(S.abstract(pspecs), S.abstract(cspecs),
                                inputs)

        return lower, cfg

    # decode: one new token against a cache holding shp.seq tokens
    def serve_step(params, cache, inp, pos):
        logits, cache, _ = api.apply(
            params, cfg, inp["tokens"], recipe=recipe, mode="decode",
            cache=cache, pos=pos)
        return logits[:, 0], cache

    def lower():
        jitted = jax.jit(
            serve_step,
            in_shardings=(pshard, cshard, ishard, None),
            out_shardings=(None, cshard),
        )
        return jitted.lower(S.abstract(pspecs), S.abstract(cspecs), inputs,
                            jax.ShapeDtypeStruct((), jnp.int32))

    return lower, cfg


_VERDICT_RANK = ("fallback", "capped-alpha", "data-dependent", "certified",
                 "n/a")


def qcert_for(cfg) -> dict:
    """Static overflow verdicts for the serve recipe at this arch's
    contraction sizes (repro.analysis certificates, no tensors)."""
    from repro.core.recipe import certify_recipe

    dims = {"d_model": cfg.d_model, "d_ff": cfg.d_ff}
    if getattr(cfg, "moe_d_ff", 0):
        dims["moe_d_ff"] = cfg.moe_d_ff
    return certify_recipe(DEFAULT_RECIPE, dims)


def _qcert_worst(verdicts: dict) -> str:
    real = [v for v in verdicts.values() if v != "n/a"]
    if not real:
        return "n/a"
    return min(real, key=_VERDICT_RANK.index)


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
             collect_hlo: bool = True, cfg_overrides: dict | None = None,
             rules=None, token_sharding=None) -> dict:
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "multi_pod": multi_pod}
    cfg = get_arch(arch)
    shp = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shp)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    try:
        lower_fn, cfg2 = build_cell(arch, shape_name, mesh, multi_pod,
                                    cfg_overrides=cfg_overrides,
                                    rules=rules,
                                    token_sharding=token_sharding)
        with mesh:
            lowered = lower_fn()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = normalized_cost_analysis(compiled)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 1),
            compile_s=round(t2 - t1, 1),
            param_count=cfg2.param_count_estimate(),
            memory={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "code_bytes": int(mem.generated_code_size_in_bytes),
            },
            cost={
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "transcendentals": float(cost.get("transcendentals", 0.0)),
            },
        )
        if shp.kind != "train":
            # serve cells run the quantized recipe: attach the static
            # overflow-certificate verdicts for its contraction sizes
            rec["qcert"] = qcert_for(cfg2)
            rec["qcert_worst"] = _qcert_worst(rec["qcert"])
        if collect_hlo:
            txt = compiled.as_text()
            rec["collectives"] = parse_collectives(txt)
            rec["hlo_convert_count"] = txt.count(" convert(")
            del txt
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "placeholder devices missing"
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    meshes = {mp: make_production_mesh(multi_pod=mp) for mp in pods}
    n_ok = n_skip = n_err = 0
    with open(args.out, "a") as f:
        for mp in pods:
            for arch in archs:
                for shape in shapes:
                    rec = run_cell(arch, shape, meshes[mp], mp,
                                   collect_hlo=not args.no_hlo)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    tag = rec["status"]
                    n_ok += tag == "ok"
                    n_skip += tag == "skipped"
                    n_err += tag == "error"
                    msg = rec.get("error", rec.get("reason", ""))[:90]
                    extra = ""
                    if tag == "ok":
                        gb = rec["memory"]["argument_bytes"] / 2**30
                        extra = (f"args/dev={gb:.2f}GiB "
                                 f"flops/dev={rec['cost']['flops']:.3g} "
                                 f"lower={rec['lower_s']}s "
                                 f"compile={rec['compile_s']}s")
                        if "qcert_worst" in rec:
                            extra += f" qcert={rec['qcert_worst']}"
                    print(f"[{rec['mesh']}] {arch} x {shape}: {tag} "
                          f"{extra}{msg}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    _telemetry_cell()
    if n_err:
        raise SystemExit(1)


def _telemetry_cell() -> None:
    """Print the dry-run's registry snapshot: certificate verdicts, any
    quantization-health counters ticked while lowering the serve cells,
    and p50/p95/p99 for any ``*_seconds`` histograms (e.g. ptq/lowering
    spans) — everything here is eager/offline, the obs no-jit rule is
    moot."""
    from repro import obs

    snap = obs.default_registry().snapshot()
    c = snap["counters"]
    cells = []
    for name in ("qcert_verdicts_total", "quantized_layers_total",
                 "alpha_cap_events_total", "int_scale_floor_hits_total",
                 "amax_floor_hits_total"):
        if c.get(name):
            cells.append(f"{name}={c[name]}")
    print("[dryrun] telemetry: " + ("; ".join(cells) if cells
                                    else "no counters ticked"))
    for name, series in sorted(snap["histograms"].items()):
        if not name.endswith("_seconds"):
            continue
        for sk, st in sorted(series.items()):
            if not st["count"]:
                continue
            q = st["quantiles"]
            print(f"[dryrun] {name}{{{sk}}}: n={st['count']} "
                  f"p50={q['p50'] * 1e3:.2f}ms "
                  f"p95={q['p95'] * 1e3:.2f}ms "
                  f"p99={q['p99'] * 1e3:.2f}ms")


if __name__ == "__main__":
    main()
