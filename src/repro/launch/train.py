"""Training driver: data -> train_step loop -> checkpoints, fault-tolerant.

CPU-scale end-to-end runs (examples/quickstart.py) and the same loop
structure a cluster deployment would use: deterministic sharded data,
heartbeat/straggler hooks, async atomic checkpoints, restart-from-latest.

    PYTHONPATH=src python -m repro.launch.train --steps 200 --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed.fault import FailureInjector, Heartbeat
from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.nn import spec as S
from repro.training import optimizer as O
from repro.training.train_step import make_train_step


def train_loop(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    opt_cfg: O.AdamWConfig,
    *,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    log_every: int = 10,
    fail_at_step: int | None = None,
    grad_accum: int = 1,
    log_fn=print,
):
    """Returns (params, opt_state, history). Restarts from the latest
    checkpoint in ckpt_dir if one exists (fault tolerance drill)."""
    api = get_model(cfg)
    pspecs = api.param_specs(cfg, None)
    ospecs = O.state_specs(pspecs)
    pipe = SyntheticPipeline(data_cfg)
    step_fn = jax.jit(make_train_step(api, cfg, opt_cfg,
                                      grad_accum=grad_accum))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    injector = FailureInjector(fail_at_step)
    hb = Heartbeat()

    start = 0
    if mgr and mgr.latest_step() is not None:
        start = mgr.latest_step()
        tmpl = {"params": S.abstract(pspecs), "opt": S.abstract(ospecs)}
        state, meta = mgr.restore(start, tmpl)
        params, opt_state = state["params"], state["opt"]
        log_fn(f"[train] restored checkpoint at step {start}")
    else:
        params = S.materialize(pspecs, jax.random.PRNGKey(seed))
        opt_state = S.materialize(ospecs, jax.random.PRNGKey(seed + 1))

    history = []
    try:
        for step in range(start, steps):
            injector.maybe_fail(step)
            hb.start()
            batch = {k: jnp.asarray(v)
                     for k, v in pipe.global_batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = hb.stop(step)
            history.append({"step": step, "loss": loss, "dt": dt})
            if step % log_every == 0 or step == steps - 1:
                log_fn(f"[train] step {step:5d} loss {loss:.4f} "
                       f"({dt:.2f}s/step)")
            if mgr and ((step + 1) % ckpt_every == 0 or step == steps - 1):
                mgr.save_async(step + 1,
                               {"params": params, "opt": opt_state},
                               meta={"loss": loss})
    finally:
        # preemption safety: never lose an in-flight checkpoint, even when
        # a node failure (or injected drill) aborts the loop mid-step
        if mgr:
            mgr.wait()
    return params, opt_state, history


def main() -> None:
    from repro.configs.paper_llama import tiny_lm

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="results/tiny_lm_ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = tiny_lm()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          batch_size=args.batch)
    opt_cfg = O.AdamWConfig(lr=args.lr, warmup_steps=20,
                            total_steps=args.steps)
    t0 = time.time()
    _, _, hist = train_loop(cfg, data_cfg, opt_cfg, steps=args.steps,
                            ckpt_dir=args.ckpt)
    print(f"[train] done in {time.time()-t0:.0f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
