"""Production mesh builders (assignment spec).

Functions, not module-level constants — importing this module never touches
jax device state (critical: device count locks on first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (data, model); multi-pod adds the pod axis:
    2 pods = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally (tests / CPU runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
