import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first two lines — see dryrun.py.

"""§Perf hillclimb driver: baseline + optimized variants for the three
selected cells, each re-lowered on the production mesh with the change
verified in the compiled HLO (dtype of collectives, memory_analysis,
convert counts), alongside analytic before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb

Writes results/hillclimb.json consumed by EXPERIMENTS.md §Perf.
"""
import json
import re
import time

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh
from repro.models import moe


def serve_rules_dp_seq():
    """xlstm variant: weights fully replicated (1.3B int4 fits per chip);
    activations sharded batch->data, seq->model -> zero TP all-reduces on
    the projection path; the mLSTM chunk recurrence is the only cross-seq
    dependency left."""
    return (
        ("embed", None), ("mlp", None), ("mlp2", None), ("moe_mlp", None),
        ("heads_q", None), ("heads_kv", None), ("q_lora", None),
        ("kv_lora", None), ("vocab", None), ("experts", None),
        ("layers", None), ("cache_batch", "data"), ("cache_seq", None),
    )


CELLS = [
    {
        "cell": ("qwen2-72b", "decode_32k"),
        "why": "most representative of the paper's technique: the "
               "quantized W4A8-IS serving step, memory-bound",
        "variants": [
            ("baseline-w4a8-is-bf16kv", {}, {}),
            ("int8-kv-cache", {"cfg_overrides": {"kv_cache_dtype": "int8"}},
             {"hypothesis": "KV reads dominate (5.4 of 7.9 GB/chip); int8 "
                            "KV halves them -> step 9.6->6.4ms (1.5x)"}),
        ],
    },
    {
        "cell": ("deepseek-v2-236b", "train_4k"),
        "why": "most collective-bound cell (tx 20.9s vs tc 3.7s): MoE "
               "all-to-all + TP all-reduces + FSDP gathers",
        "variants": [
            ("baseline-fsdp-tp-ep", {}, {}),
            ("int8-moe-dispatch",
             {"cfg_overrides": {"moe_int8_dispatch": True},
              "dispatch_sharding": True},
             {"hypothesis": "dispatch a2a carries bf16 (12.2s of tx); "
                            "int8 transport halves it -> tx 20.9->14.8s"}),
        ],
    },
    {
        "cell": ("xlstm-1.3b", "prefill_32k"),
        "why": "worst roofline fraction (0.044): collective-bound TP "
               "serving of a small recurrent model + 32768-step scan",
        "variants": [
            ("baseline-tp-scan", {}, {}),
            ("chunked-mlstm",
             {"cfg_overrides": {"mlstm_impl": "chunked",
                                "chunk_size": 256}},
             {"hypothesis": "chunkwise-parallel cell cuts sequential "
                            "depth 32768->128; terms unchanged, latency "
                            "bound (not in 3-term model) collapses"}),
            ("chunked+replicated-weights",
             {"cfg_overrides": {"mlstm_impl": "chunked",
                                "chunk_size": 256},
              "rules": serve_rules_dp_seq(),
              "token_sharding": P("data", "model")},
             {"hypothesis": "weights replicated (0.75 GiB int4/chip) + "
                            "tokens sharded over all 256 chips -> TP "
                            "all-reduces (483ms) vanish; leftover "
                            "collectives only from the chunk-state chain"}),
        ],
    },
]


def scan_trip_info(hlo: str) -> list[int]:
    """Trip counts of while loops (from constant comparisons) — evidence
    for the sequential-depth claims."""
    # XLA encodes trip counts in while conditions like s32[] constant(128)
    out = [int(m) for m in re.findall(
        r"while.*?trip_count=(\d+)", hlo)]
    if not out:
        out = [int(m) for m in re.findall(
            r'known_trip_count=\{"n":"(\d+)"\}', hlo)]
    return sorted(out, reverse=True)[:8]


def main() -> None:
    assert len(jax.devices()) == 512
    mesh = make_production_mesh(multi_pod=False)
    results = []
    for spec in CELLS:
        arch, shape = spec["cell"]
        for name, opts, meta in spec["variants"]:
            if opts.get("dispatch_sharding"):
                moe.set_dispatch_sharding(
                    NamedSharding(mesh, P("data", "model", None, None)),
                    NamedSharding(mesh, P("data", "model", None, None)))
            else:
                moe._DISPATCH_SHARDING = None
            t0 = time.time()
            rec = run_cell(
                arch, shape, mesh, False,
                cfg_overrides=opts.get("cfg_overrides"),
                rules=opts.get("rules"),
                token_sharding=opts.get("token_sharding"))
            rec.update(variant=name, cell_why=spec["why"], **meta)
            # extra HLO evidence: int8 collectives + loop trip counts
            results.append(rec)
            msg = rec["status"]
            if rec["status"] == "ok":
                gb = rec["memory"]["argument_bytes"] / 2**30
                cw = rec.get("collectives", {}).get("total_wire_bytes", 0)
                msg = (f"args/dev={gb:.2f}GiB wire/dev={cw/2**30:.3f}GiB "
                       f"converts={rec.get('hlo_convert_count')}")
            print(f"[hillclimb] {arch}/{shape} :: {name}: {msg} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    os.makedirs("results", exist_ok=True)
    with open("results/hillclimb.json", "w") as f:
        json.dump(results, f, indent=1)
    errs = [r for r in results if r["status"] != "ok"]
    if errs:
        for e in errs:
            print("ERROR:", e["arch"], e["shape"], e.get("variant"),
                  e.get("error"))
        raise SystemExit(1)


if __name__ == "__main__":
    main()
