"""Serving driver: load a checkpoint, PTQ per recipe, run the continuous-
batching engine over a stream of requests.

    PYTHONPATH=src:. python -m repro.launch.serve --algo gptq --requests 8 \
        --scale-mode integer
"""
from __future__ import annotations

import argparse
import time

from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.serving.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="rtn",
                    choices=["rtn", "gptq", "awq", "smoothquant",
                             "omniquant"])
    ap.add_argument("--scale-mode", default="integer",
                    choices=["integer", "float"])
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--group", type=int, default=128)
    ap.add_argument("--amplifier", default="1024")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fp", action="store_true",
                    help="serve unquantized (baseline)")
    ap.add_argument("--kernel-mode", default="reference",
                    choices=["reference", "pallas", "pallas_interpret"],
                    help="qlinear backend inside prefill/decode")
    args = ap.parse_args()

    from benchmarks.common import calib_batches, load_bench_model

    api, cfg, params, trained = load_bench_model()
    print(f"[serve] model={cfg.name} trained={trained}")
    if args.fp:
        recipe, qparams = None, params
    else:
        amp = (args.amplifier if not args.amplifier.isdigit()
               else int(args.amplifier))
        spec = QuantSpec(w_bits=args.w_bits, a_bits=args.a_bits,
                         group_size=args.group, scale_mode=args.scale_mode,
                         amplifier=amp, algo=args.algo)
        recipe = QuantRecipe(rules=(("*", spec),), name=spec.name)
        t0 = time.time()
        qparams = ptq.post_training_quantize(api, cfg, params, recipe,
                                             calib_batches(1))
        print(f"[serve] quantized ({spec.name}) in {time.time()-t0:.1f}s")

    sc = ServeConfig(max_slots=args.slots, max_seq=128, prefill_len=32,
                     max_new_tokens=args.max_new,
                     temperature=args.temperature,
                     kernel_mode=args.kernel_mode)
    eng = Engine(api, cfg, qparams, sc, recipe=recipe)
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, batch_size=1))
    for i in range(args.requests):
        eng.submit(pipe.batch(300_000 + i)["tokens"][0].tolist())
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    total = sum(len(v) for v in outs.values())
    print(f"[serve] {len(outs)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s, {eng.ticks} decode ticks)")
    for rid in sorted(outs)[:4]:
        print(f"[serve] r{rid}: {outs[rid][:16]}...")


if __name__ == "__main__":
    main()
