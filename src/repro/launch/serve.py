"""Serving driver: load a checkpoint, PTQ per recipe, run the continuous-
batching engine over a stream of requests.

    PYTHONPATH=src:. python -m repro.launch.serve --algo gptq --requests 8 \
        --scale-mode integer

``--arch mixtral-8x7b`` swaps the trained bench_lm for a smoke-shaped
registry architecture (random init) — the quantized-MoE ragged decode
path. ``--metrics-out PATH`` writes the run's telemetry as JSONL: one
event per line (submit / admit / tick / retire / counters / trace /
ptq_run) with a trailing ``{"snapshot": ...}`` line carrying every
counter/gauge/histogram — per-tick decode latency (host and device),
TTFT/TPOT with p50/p95/p99, executed-vs-total ragged m-tiles,
capped-alpha counts. The telemetry outputs are flushed in a ``finally``
block, so a tick that raises still leaves the event log + snapshot on
disk (exactly when it is most needed). ``--trace-out PATH`` exports the
same event log as a Perfetto/chrome://tracing timeline (engine-phase
lane, per-request-slot lifecycle lanes, m-tile/qgemm counter tracks —
open at https://ui.perfetto.dev). ``--profile-dir DIR`` additionally
wraps the serving loop in a ``jax.profiler.trace`` capture window. A
telemetry cell summarizing the snapshot is always printed, and
steady-state ``decode_traces == 1 + fallbacks`` is asserted so
instrumentation can never silently add a retrace (each circuit-breaker
fallback re-establishes the jitted decode: exactly one intentional extra
trace).

Robustness knobs: ``--deadline-s`` / ``--max-queue`` /
``--truncate-prompts`` / ``--breaker-threshold`` /
``--fallback-kernel-mode`` map onto the ``ServeConfig`` lifecycle
hardening, and ``--chaos-nan-ticks`` / ``--chaos-kernel-ticks`` arm the
``repro.serving.chaos`` fault drill (nightly CI injects NaNs and asserts
the ``nan`` outcome lands in the metrics artifact + as distinct
``retire:nan`` Perfetto markers).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import obs
from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.nn import spec as S
from repro.serving.engine import Engine, ServeConfig


def _load_model(arch: str):
    if arch == "bench-lm":
        from benchmarks.common import load_bench_model

        return load_bench_model()
    from repro.models.registry import get_arch, get_model

    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    return api, cfg, params, False


def _fmt_hist(h: dict) -> str:
    n = h["count"]
    if not n:
        return "n=0"
    out = f"n={n} mean={h['sum'] / n * 1e3:.2f}ms"
    q = h.get("quantiles")
    if q:
        out += (f" p50={q['p50'] * 1e3:.2f}ms p95={q['p95'] * 1e3:.2f}ms"
                f" p99={q['p99'] * 1e3:.2f}ms")
    return out


def _telemetry_cell(reg: obs.Registry) -> None:
    snap = reg.snapshot()
    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]

    def csum(name: str) -> float:
        return sum(c.get(name, {}).values())

    print("[serve] --- telemetry ---------------------------------------")
    print(f"[serve] ticks={int(csum('engine_ticks_total'))} "
          f"tokens={int(csum('engine_tokens_total'))} "
          f"requests={c.get('engine_requests_total', {})} "
          f"queue_depth={g.get('engine_queue_depth', {}).get('', 0)}")
    # request outcomes + the conservation law (sums to submitted)
    outcomes = c.get("engine_request_outcomes_total", {})
    submitted = c.get("engine_requests_total", {}).get(
        'event="submitted"', 0)
    if outcomes:
        conserved = sum(outcomes.values()) == submitted
        pretty = {k: int(v) for k, v in sorted(outcomes.items())}
        print(f"[serve] outcomes={pretty} submitted={int(submitted)} "
              f"conserved={'yes' if conserved else 'NO'}")
    if csum("engine_fallback_events_total"):
        print(f"[serve] breaker fallbacks="
              f"{c.get('engine_fallback_events_total', {})} "
              f"kernel_failures="
              f"{c.get('engine_kernel_failures_total', {})}")
    if csum("engine_slow_ticks_total"):
        print(f"[serve] slow_ticks="
              f"{int(csum('engine_slow_ticks_total'))}")
    phases = h.get("engine_phase_seconds", {})
    for sk in sorted(phases):
        print(f"[serve] phase {sk or '<all>'}: {_fmt_hist(phases[sk])}")
    # device-time attribution: host phase span minus this = host overhead
    for sk, st in sorted(h.get("engine_phase_device_seconds", {}).items()):
        print(f"[serve] device {sk or '<all>'}: {_fmt_hist(st)}")
    for name in ("engine_ttft_seconds", "engine_tpot_seconds"):
        for sk, st in h.get(name, {}).items():
            print(f"[serve] {name}{('{' + sk + '}') if sk else ''}: "
                  f"{_fmt_hist(st)}")
    tiles = c.get("engine_moe_m_tiles_total", {})
    if tiles:
        ex = tiles.get('kind="executed"', 0)
        tot = tiles.get('kind="total"', 0)
        frac = f" ({ex / tot:.2f}x dense)" if tot else ""
        print(f"[serve] moe m-tiles executed/total={int(ex)}/{int(tot)}"
              f"{frac}")
    for name in ("qgemm_calls_total", "engine_traces_total",
                 "qcert_verdicts_total"):
        if c.get(name):
            print(f"[serve] {name}: {c[name]}")
    print(f"[serve] alpha_cap_events_total="
          f"{int(csum('alpha_cap_events_total'))} "
          f"int_scale_floor_hits_total="
          f"{int(csum('int_scale_floor_hits_total'))} "
          f"amax_floor_hits={c.get('amax_floor_hits_total', {})}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bench-lm",
                    help="bench-lm (trained ckpt if present) or a registry "
                         "architecture run at smoke shape, e.g. "
                         "mixtral-8x7b")
    ap.add_argument("--algo", default="rtn",
                    choices=["rtn", "gptq", "awq", "smoothquant",
                             "omniquant"])
    ap.add_argument("--scale-mode", default="integer",
                    choices=["integer", "float"])
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--group", type=int, default=128)
    ap.add_argument("--amplifier", default="1024")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fp", action="store_true",
                    help="serve unquantized (baseline)")
    ap.add_argument("--kernel-mode", default="reference",
                    choices=["reference", "pallas", "pallas_interpret"],
                    help="qlinear backend inside prefill/decode")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none); "
                         "overruns retire with outcome=timeout")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="admission queue bound (0 = unbounded); surplus "
                         "submits are rejected (backpressure)")
    ap.add_argument("--truncate-prompts", action="store_true",
                    help="opt into clipping over-length prompts to "
                         "prefill-len instead of rejecting them")
    ap.add_argument("--breaker-threshold", type=int, default=3,
                    help="consecutive kernel failures / poisoned ticks "
                         "that trip the fallback circuit breaker")
    ap.add_argument("--fallback-kernel-mode", default="reference",
                    choices=["reference", "pallas", "pallas_interpret",
                             "none"],
                    help="kernel mode the breaker degrades to "
                         "('none' disables mode fallback)")
    ap.add_argument("--chaos-nan-ticks", default="",
                    help="comma-separated decode ticks at which to inject "
                         "NaN logits into every active slot "
                         "(repro.serving.chaos fault drill)")
    ap.add_argument("--chaos-kernel-ticks", default="",
                    help="comma-separated decode ticks at which to inject "
                         "one kernel exception (breaker drill)")
    ap.add_argument("--metrics-out", default="",
                    help="write telemetry JSONL (events + final snapshot "
                         "line) to this path")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto/chrome://tracing timeline JSON "
                         "of the run to this path (ui.perfetto.dev)")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the serving "
                         "loop into this directory (TensorBoard profile "
                         "plugin format)")
    args = ap.parse_args()

    reg = obs.default_registry()
    api, cfg, params, trained = _load_model(args.arch)
    print(f"[serve] model={cfg.name} trained={trained}")
    if args.fp:
        recipe, qparams = None, params
    else:
        amp = (args.amplifier if not args.amplifier.isdigit()
               else int(args.amplifier))
        spec = QuantSpec(w_bits=args.w_bits, a_bits=args.a_bits,
                         group_size=args.group, scale_mode=args.scale_mode,
                         amplifier=amp, algo=args.algo)
        recipe = QuantRecipe(rules=(("*", spec),), name=spec.name)
        calib = None
        if args.arch == "bench-lm":
            from benchmarks.common import calib_batches

            calib = calib_batches(1)
        t0 = time.time()
        qparams = ptq.post_training_quantize(api, cfg, params, recipe,
                                             calib)
        print(f"[serve] quantized ({spec.name}) in {time.time()-t0:.1f}s")

    fb = args.fallback_kernel_mode
    sc = ServeConfig(max_slots=args.slots, max_seq=args.max_seq,
                     prefill_len=args.prefill_len,
                     max_new_tokens=args.max_new,
                     temperature=args.temperature,
                     kernel_mode=args.kernel_mode,
                     deadline_s=args.deadline_s,
                     max_queue=args.max_queue,
                     truncate_prompts=args.truncate_prompts,
                     breaker_threshold=args.breaker_threshold,
                     fallback_kernel_mode=None if fb == "none" else fb)
    eng = Engine(api, cfg, qparams, sc, recipe=recipe)
    if args.chaos_nan_ticks or args.chaos_kernel_ticks:
        from repro.serving import chaos

        ccfg = chaos.ChaosConfig(
            nan_logits=tuple(
                chaos.NanFault(tick=int(t))
                for t in args.chaos_nan_ticks.split(",") if t),
            kernel_failures=tuple(
                chaos.KernelFault(tick=int(t))
                for t in args.chaos_kernel_ticks.split(",") if t))
        monkey = chaos.ChaosMonkey(ccfg).install(eng)
        print(f"[serve] chaos armed: nan_ticks="
              f"[{args.chaos_nan_ticks}] kernel_ticks="
              f"[{args.chaos_kernel_ticks}]")
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.prefill_len,
                                        batch_size=1))
    for i in range(args.requests):
        eng.submit(pipe.batch(300_000 + i)["tokens"][0].tolist())
    # flush-on-failure: the event log + snapshot (and the timeline) are
    # written even when a tick raises — the crashing run is the one whose
    # telemetry matters most.
    try:
        with obs.trace_window(args.profile_dir or None):
            t0 = time.time()
            outs = eng.run()
            dt = time.time() - t0
        total = sum(len(v) for v in outs.values())
        print(f"[serve] {len(outs)} requests, {total} tokens in {dt:.1f}s "
              f"({total/dt:.1f} tok/s, {eng.ticks} decode ticks)")
        for rid in sorted(outs)[:4]:
            print(f"[serve] r{rid}: {outs[rid][:16]}...")

        # instrumentation must add zero retraces: row_counts stay traced
        # operands, so steady-state decode compiles exactly once per
        # established kernel route (each breaker fallback re-establishes
        # the route = exactly one intentional extra trace)
        assert eng.decode_traces == 1 + eng.fallbacks, \
            (f"decode retraced {eng.decode_traces}x with "
             f"{eng.fallbacks} fallbacks — telemetry broke jit")
    finally:
        _telemetry_cell(reg)
        if args.metrics_out:
            n = reg.write_events_jsonl(args.metrics_out)
            print(f"[serve] wrote {n} telemetry lines -> "
                  f"{args.metrics_out}")
        if args.trace_out:
            n = obs.write_trace(args.trace_out, reg)
            print(f"[serve] wrote {n} trace events -> {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")
        if args.profile_dir:
            print(f"[serve] jax profiler capture -> {args.profile_dir}")


if __name__ == "__main__":
    main()
