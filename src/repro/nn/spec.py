"""Parameter-spec system: the single source of truth for parameters.

A model is described by a pytree (nested dicts) of :class:`ParamSpec` leaves.
From that one tree we derive
  * real arrays            (``materialize`` — used by CPU-scale runs/tests),
  * ShapeDtypeStructs      (``abstract`` — used by the dry-run, NO allocation),
  * PartitionSpecs         (``partition_specs`` — logical->mesh axis rules).

This replaces flax's ``param``/``with_logical_partitioning`` machinery with a
small explicit core so the whole framework is pure JAX.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any

# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor.

    ``logical_axes`` names each dim with a *logical* axis ("embed", "mlp",
    "heads", ...).  Sharding rules (see :func:`partition_specs`) map logical
    axes to physical mesh axes per (arch x shape x mesh) so the same model
    code serves training FSDP, serving TP, etc.
    """

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | scaled_normal
    logical_axes: tuple[str | None, ...] = ()
    init_scale: float = 1.0  # multiplier on the default fan-in scale

    def __post_init__(self):
        if self.logical_axes and len(self.logical_axes) != len(self.shape):
            raise ValueError(
                f"logical_axes {self.logical_axes} rank != shape {self.shape}"
            )

    # -- derivations ---------------------------------------------------------
    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            std = 1.0 * self.init_scale
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(
                self.dtype
            )
        # fan-in scaled normal for weight matrices (last-but-one dim = fan_in
        # for 2D [in, out]; use first dim product otherwise).
        if len(self.shape) >= 2:
            fan_in = int(math.prod(self.shape[:-1]))
        else:
            fan_in = max(1, self.shape[0] if self.shape else 1)
        std = self.init_scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(
            self.dtype
        )


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Pytree) -> Pytree:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Tree derivations
# ---------------------------------------------------------------------------


def abstract(tree: Pytree) -> Pytree:
    """ShapeDtypeStruct tree — safe for .lower() without any allocation."""
    return _tree_map_specs(lambda s: s.abstract(), tree)


def materialize(tree: Pytree, key: jax.Array) -> Pytree:
    """Instantiate real arrays. Key is split deterministically by flat index."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [spec.materialize(k) for spec, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def param_count(tree: Pytree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


def param_bytes(tree: Pytree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(
        int(math.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves
    )


# ---------------------------------------------------------------------------
# Logical -> physical sharding rules
# ---------------------------------------------------------------------------

# A rule maps a logical axis name to a mesh axis name (or tuple of them, or
# None for replication). First matching rule wins; unlisted logical axes are
# replicated.
Rules = Sequence[tuple[str, str | tuple[str, ...] | None]]


def logical_to_pspec(
    logical_axes: Sequence[str | None],
    rules: Rules,
    mesh_axis_sizes: Mapping[str, int] | None = None,
    shape: Sequence[int] | None = None,
) -> P:
    """Map logical axis names to a PartitionSpec via `rules`.

    If ``mesh_axis_sizes`` and ``shape`` are given, a mapping whose mesh-axis
    product does not divide the dim size is dropped (replicated instead) —
    this keeps one rule table usable across full + smoke configs.
    """
    rule_map = dict(rules)
    used: set[str] = set()
    entries: list[Any] = []
    for i, name in enumerate(logical_axes):
        target = rule_map.get(name) if name is not None else None
        if target is None:
            entries.append(None)
            continue
        axes = (target,) if isinstance(target, str) else tuple(target)
        # drop already-used mesh axes (a mesh axis may appear only once)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            entries.append(None)
            continue
        if mesh_axis_sizes is not None and shape is not None:
            prod = math.prod(mesh_axis_sizes.get(a, 1) for a in axes)
            if prod == 0 or shape[i] % max(prod, 1) != 0:
                entries.append(None)
                continue
        used.update(axes)
        entries.append(axes[0] if len(axes) == 1 else axes)
    # trim trailing Nones for tidier specs
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def partition_specs(
    tree: Pytree,
    rules: Rules,
    mesh: jax.sharding.Mesh | None = None,
) -> Pytree:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None

    def one(s: ParamSpec) -> P:
        return logical_to_pspec(s.logical_axes, rules, sizes, s.shape)

    return _tree_map_specs(one, tree)


def named_shardings(
    tree: Pytree, rules: Rules, mesh: jax.sharding.Mesh
) -> Pytree:
    from jax.sharding import NamedSharding

    pspecs = partition_specs(tree, rules, mesh)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------


def w(shape, axes, dtype=jnp.float32, init="normal", scale=1.0) -> ParamSpec:
    return ParamSpec(tuple(shape), dtype, init, tuple(axes), scale)


def zeros(shape, axes, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), dtype, "zeros", tuple(axes))


def ones(shape, axes, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), dtype, "ones", tuple(axes))
