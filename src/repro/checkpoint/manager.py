"""Fault-tolerant checkpointing: atomic, async, retained, elastic.

* atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` -> a crash
  mid-save never corrupts the latest checkpoint.
* async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread — the train loop never blocks on disk.
* retention: keep the most recent ``keep`` checkpoints.
* elastic: ``restore`` takes the ParamSpec tree + target shardings, so the
  same checkpoint restores onto a *different* mesh (re-shard on load) — the
  restart path after node failure or cluster resize.

Storage: one .npz per checkpoint (flat key -> array). For multi-host
deployments each host would write its shards (process-local arrays); in
this single-process container full arrays are written.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# dtypes numpy can't serialize natively -> stored as a same-width uint view
_VIEWED = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template: Any, flat: dict[str, Any], prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- paths ---------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                out.append(int(f[5:-4]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------
    def _write(self, step: int, flat_np: dict[str, np.ndarray],
               meta: dict) -> None:
        viewed = {}
        enc = {}
        for k, v in flat_np.items():
            name = str(v.dtype)
            if name in _VIEWED:
                enc[k] = v.view(_VIEWED[name][1])
                viewed[k] = name
            else:
                enc[k] = v
        meta = dict(meta or {}, __viewed__=viewed)
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **enc)
        os.replace(tmp, self._path(step))  # atomic
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    def save(self, step: int, tree: Any, meta: dict | None = None) -> None:
        flat = _flatten(tree)
        flat_np = {k: np.asarray(v) for k, v in flat.items()}
        self._write(step, flat_np, meta or {})

    def save_async(self, step: int, tree: Any,
                   meta: dict | None = None) -> None:
        """Snapshot to host now, write in the background."""
        self.wait()  # one in-flight save at a time
        flat = _flatten(tree)
        flat_np = {k: np.asarray(v) for k, v in flat.items()}  # device->host

        def run():
            try:
                self._write(step, flat_np, meta or {})
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore -----------------------------------------------------------------
    def restore(self, step: int, template: Any,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """template: pytree of arrays or ShapeDtypeStructs (same structure).
        shardings: optional matching NamedSharding tree -> device_put onto
        the *current* mesh (elastic re-shard)."""
        with np.load(self._path(step), allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            viewed = meta.pop("__viewed__", {})
            flat = {}
            for k in z.files:
                if k == "__meta__":
                    continue
                a = z[k]
                if k in viewed:
                    a = a.view(_VIEWED[viewed[k]][0])
                flat[k] = a
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(jnp.asarray(a), s),
                tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree, meta
