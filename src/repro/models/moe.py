"""Mixture-of-Experts with sort-based capacity dispatch + expert parallelism.

Design (DESIGN.md §3):
  router (fp32) -> top-k -> stable sort by expert -> gather into a dense
  (groups, E, C, d) dispatch buffer -> batched expert GEMMs with the expert
  axis sharded on the ``model`` mesh axis (EP; GSPMD inserts the all-to-all)
  -> weighted scatter-combine. Tokens beyond capacity are dropped (GShard).

Expert FFN weights may be quantized (paper §5.5 — Mixtral): the batched
expert GEMM runs the fused grouped integer-scale Pallas kernel
(``repro.kernels.moe_gemm``) under kernel mode "pallas"/"pallas_interpret",
and a vmapped fine-grained reference GEMM otherwise — either way the HLO
contains int8 dot_generals per expert.

Shared experts (DeepSeek-V2) are a plain always-on MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.nn import spec as S
from .config import ModelConfig
from .mlp import mlp_apply, mlp_specs


# ---------------------------------------------------------------------------
# Expert-stacked linears (leading E dim), recipe-aware
# ---------------------------------------------------------------------------


def expert_linear_specs(E: int, K: int, N: int, qspec, axes, dtype) -> dict:
    base = qlinear.linear_specs(K, N, qspec, axes[1:], dtype=dtype)

    def stack(s: S.ParamSpec) -> S.ParamSpec:
        return S.ParamSpec((E, *s.shape), s.dtype, s.init,
                           (axes[0], *s.logical_axes), s.init_scale)

    return jax.tree.map(stack, base, is_leaf=S.is_spec)


def expert_linear_apply(params: dict, x: jax.Array, qspec,
                        row_counts: jax.Array | None = None, *,
                        mode: str | None = None) -> jax.Array:
    """x: (E, C, K) -> (E, C, N), all experts in one call.

    Quantized experts route through ``qlinear.grouped_linear_apply``: under
    kernel mode "pallas"/"pallas_interpret" that is ONE fused grouped
    Pallas GEMM over the (experts, m, n, k-groups) grid (kernels/moe_gemm)
    rather than a vmap of the per-expert reference GEMM. ``row_counts``
    (int32 (E,), routed rows per expert; rows past it are zero-filled by
    the dispatch) lets the ragged kernel skip capacity-padding m-tiles —
    it is a data operand (traced under jit), so the serving engine's
    decode step feeds fresh per-tick counts without retracing. ``mode`` is
    cfg.kernel_mode threaded from moe_apply.
    """
    return qlinear.grouped_linear_apply(params, x, qspec,
                                        row_counts=row_counts, mode=mode)


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------


# -- int8 dispatch compression (§Perf hillclimb, DeepSeek-V3-style) ---------
# The dispatch buffer crosses the data->expert resharding boundary (the
# all-to-all). Quantizing per token to int8 (+ f32 scale) halves the wire
# bytes vs bf16. The sharding constraint below is what forces GSPMD to run
# the all-to-all ON the int8 tensor (dequant lands on the expert side);
# without it XLA would transport the dequantized bf16. Gradients pass
# straight through (custom_vjp): under W4A8 the expert GEMMs re-quantize
# activations anyway, so the forward effect is one extra rounding.

_DISPATCH_SHARDING = None  # optional (q8_sharding, scale_sharding) pair


def set_dispatch_sharding(q8_sharding, scale_sharding) -> None:
    global _DISPATCH_SHARDING
    _DISPATCH_SHARDING = (q8_sharding, scale_sharding)


@jax.custom_vjp
def _int8_transport(buf: jax.Array) -> jax.Array:
    amax = jnp.max(jnp.abs(buf.astype(jnp.float32)), axis=-1, keepdims=True)
    scl = jnp.maximum(amax, 1e-8) / 127.0
    q8 = jnp.clip(jnp.round(buf.astype(jnp.float32) / scl),
                  -127, 127).astype(jnp.int8)
    if _DISPATCH_SHARDING is not None:
        q8 = jax.lax.with_sharding_constraint(q8, _DISPATCH_SHARDING[0])
        scl = jax.lax.with_sharding_constraint(scl, _DISPATCH_SHARDING[1])
    return (q8.astype(jnp.float32) * scl).astype(buf.dtype)


def _int8_transport_fwd(buf):
    return _int8_transport(buf), None


def _int8_transport_bwd(_, g):
    return (g,)  # straight-through


_int8_transport.defvjp(_int8_transport_fwd, _int8_transport_bwd)


# -- routing sinks ----------------------------------------------------------
# Observability hook for the serving engine + benchmarks: while any sink is
# registered, moe_apply stages a jax.debug.callback that delivers the
# per-expert routed (capacity-clipped) counts of every MoE layer invocation
# as {"counts": np (G, E), "capacity": int} records, so per-tick executed-
# m-tile accounting can be derived from the LIVE engine dispatch. The
# callback is STAGED at trace time — register a sink BEFORE the first
# (re)compile of the function you want observed; when no sink is active at
# trace time, compiled code carries no callback at all (zero overhead).
# Dispatch to sinks happens at execution time, so sinks added after the
# trace (while at least one was active) still receive records. Sinks may be
# plain callables or weakref-wrapped methods (``weakref.WeakMethod``) —
# dead weakrefs are pruned on delivery, letting the serving engine hook in
# without keeping itself alive.

_ROUTING_SINKS: list = []


def add_routing_sink(sink) -> None:
    """Register ``sink(record: dict)`` (or a weakref to one)."""
    _ROUTING_SINKS.append(sink)


def remove_routing_sink(sink) -> None:
    if sink in _ROUTING_SINKS:
        _ROUTING_SINKS.remove(sink)


def routing_sinks_active() -> bool:
    return bool(_ROUTING_SINKS)


def start_routing_trace() -> list:
    """Begin recording {"counts": np (G,E), "capacity": int} per MoE call.

    Convenience wrapper over the sink API: returns the live list records
    append to; pass it to :func:`stop_routing_trace` when done.
    """
    records: list = []
    add_routing_sink(records.append)
    return records


def stop_routing_trace(records: list | None = None) -> list:
    """Detach the list-sink ``start_routing_trace`` installed.

    With no argument (legacy form) every list-append sink is detached —
    callers that interleave traces should pass their own list back.
    """
    if records is not None:
        remove_routing_sink(records.append)
        return records
    out: list = []
    for s in list(_ROUTING_SINKS):
        if getattr(s, "__self__", None).__class__ is list:
            out = s.__self__
            remove_routing_sink(s)
    return out


def _record_routing(counts, *, capacity: int) -> None:
    """Host-side callback target: fan one record out to every live sink."""
    import weakref

    import numpy as np

    rec = {"counts": np.asarray(counts), "capacity": capacity}
    for s in list(_ROUTING_SINKS):
        if isinstance(s, weakref.ref):
            live = s()
            if live is None:
                remove_routing_sink(s)
                continue
            live(rec)
        else:
            s(rec)


def moe_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    dt = cfg.activation_dtype
    out = {
        "router": S.w((d, E), ("embed", None), dtype=jnp.float32),
    }
    for name in ("gate", "up"):
        qspec = recipe.spec_for(f"{base}/{name}") if recipe else None
        out[name] = expert_linear_specs(
            E, d, f, qspec, ("experts", "embed", "moe_mlp"), dt)
    qspec = recipe.spec_for(f"{base}/down") if recipe else None
    out["down"] = expert_linear_specs(
        E, f, d, qspec, ("experts", "moe_mlp", "embed"), dt)
    if cfg.num_shared_experts:
        out["shared"] = mlp_specs(
            cfg, recipe, f"{base}/shared",
            d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
    return out


def capacity(tokens: int, top_k: int, num_experts: int,
             capacity_factor: float) -> int:
    """Per-expert capacity (8-aligned) — also imported by benchmarks so
    their ragged-tile accounting can never drift from the model's."""
    c = int(tokens * top_k * capacity_factor / max(num_experts, 1))
    return max(8, -(-c // 8) * 8)


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    return capacity(tokens_per_group, cfg.top_k, cfg.num_experts,
                    cfg.capacity_factor)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig, recipe,
              base: str):
    """x: (B, S, d). Returns (y, aux_loss)."""
    B, Sq, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    G = max(1, cfg.dispatch_groups)
    T_all = B * Sq
    if T_all % G:
        G = 1
    T = T_all // G
    C = _capacity(cfg, T)
    xf = x.reshape(G, T, d)

    # --- router (fp32, never quantized) -----------------------------------
    logits = xf.astype(jnp.float32) @ params["router"]  # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G, T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (GShard/Switch) ---------------------------
    me = jnp.mean(probs, axis=1)  # (G, E) mean prob
    one_hot_top1 = jax.nn.one_hot(expert_idx[..., 0], E)
    ce = jnp.mean(one_hot_top1, axis=1)  # (G, E) dispatch fraction
    aux = cfg.router_aux_coef * E * jnp.mean(jnp.sum(me * ce, -1))

    def dispatch_one(xg, eg, gg):
        """xg (T,d), eg (T,k) int, gg (T,k) -> (y (T,d))."""
        Tk = T * k
        e_flat = eg.reshape(Tk)
        g_flat = gg.reshape(Tk)
        t_flat = jnp.repeat(jnp.arange(T), k)
        order = jnp.argsort(e_flat, stable=True)
        e_s, t_s, g_s = e_flat[order], t_flat[order], g_flat[order]
        counts = jnp.bincount(e_s, length=E)
        starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(Tk) - starts[e_s]
        keep = pos < C
        slot = e_s * C + jnp.where(keep, pos, 0)
        # dispatch buffer (E*C, d)
        buf = jnp.zeros((E * C, d), xg.dtype)
        vals = jnp.where(keep[:, None], xg[t_s], 0)
        buf = buf.at[slot].add(vals)  # kept slots unique -> add == set
        return (buf.reshape(E, C, d), (t_s, g_s, e_s, pos, keep),
                jnp.minimum(counts, C).astype(jnp.int32))

    buf, meta, counts = jax.vmap(dispatch_one)(xf, expert_idx, gate_vals)
    # buf: (G, E, C, d) — E sharded on `model` via logical axis "experts"
    # counts: (G, E) routed (capacity-clipped) rows per expert — slots at or
    # past counts[g, e] are zero-filled, which is exactly the ragged grouped
    # kernel's row_counts contract.

    if cfg.moe_int8_dispatch:
        buf = _int8_transport(buf)

    # With one dispatch group the expert slab rows [0, counts[0, e]) are
    # contiguous, so the ragged grouped kernel can skip the padded m-tiles.
    # With G > 1 the (E, G*C, d) reshape interleaves each group's padding
    # into the middle of the slab, so raggedness is not expressible as a
    # single per-expert count — fall back to the dense (exact) behavior.
    row_counts = counts[0] if G == 1 else None

    if _ROUTING_SINKS:
        import functools

        jax.debug.callback(
            functools.partial(_record_routing, capacity=C), counts)

    km = cfg.kernel_mode

    def expert_ffn(b):  # b: (G, E, C, d) -> (G, E, C, d)
        be = jnp.swapaxes(b, 0, 1).reshape(E, G * C, d)
        qs_g = recipe.spec_for(f"{base}/gate") if recipe else None
        qs_u = recipe.spec_for(f"{base}/up") if recipe else None
        qs_d = recipe.spec_for(f"{base}/down") if recipe else None
        g = expert_linear_apply(params["gate"], be, qs_g, row_counts,
                                mode=km)
        u = expert_linear_apply(params["up"], be, qs_u, row_counts,
                                mode=km)
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(be.dtype) * u)
        y = expert_linear_apply(params["down"], h, qs_d, row_counts,
                                mode=km)
        return jnp.swapaxes(y.reshape(E, G, C, d), 0, 1)

    yb = expert_ffn(buf)  # (G, E, C, d)

    def combine_one(yg, m):
        t_s, g_s, e_s, pos, keep = m
        slot = e_s * C + jnp.where(keep, pos, 0)
        vals = yg.reshape(E * C, d)[slot]  # (Tk, d)
        vals = jnp.where(keep[:, None], vals, 0) * g_s[:, None].astype(yg.dtype)
        out = jnp.zeros((T, d), yg.dtype)
        return out.at[t_s].add(vals)

    y = jax.vmap(combine_one)(yb, meta).reshape(B, Sq, d)

    if cfg.num_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg, recipe, f"{base}/shared")
    return y.astype(x.dtype), aux
