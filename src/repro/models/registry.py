"""--arch <id> registry: family -> model implementation, uniform API.

Every implementation exposes
    param_specs(cfg, recipe) -> ParamSpec tree
    cache_specs(cfg, batch, max_seq) -> ParamSpec tree (decode state)
    apply(params, cfg, tokens, *, recipe, mode, cache, pos, memory)
        -> (logits f32, new_cache, aux_loss)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    param_specs: Callable
    cache_specs: Callable
    apply: Callable


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = importlib.import_module("repro.models.transformer")
    elif cfg.family == "ssm":
        mod = importlib.import_module("repro.models.xlstm")
    elif cfg.family == "hybrid":
        mod = importlib.import_module("repro.models.griffin")
    elif cfg.family == "audio":
        mod = importlib.import_module("repro.models.encdec")
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return ModelApi(mod.param_specs, mod.cache_specs, mod.apply)


# -- architecture configs (populated by repro.configs) -----------------------

_ARCH_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str, full: Callable[[], ModelConfig],
                  smoke: Callable[[], ModelConfig]) -> None:
    _ARCH_REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke


def _ensure_loaded() -> None:
    import repro.configs  # noqa: F401  (registers everything)


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    reg = _SMOKE_REGISTRY if smoke else _ARCH_REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch '{name}'; have {sorted(reg)}")
    return reg[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_ARCH_REGISTRY)
