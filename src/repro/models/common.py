"""Shared model-building helpers: recipe-aware linears, norms, stacking."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.recipe import QuantRecipe
from repro.nn import spec as S


# ---------------------------------------------------------------------------
# Recipe-aware linear declaration/apply (paths must match between the two)
# ---------------------------------------------------------------------------


def linear(recipe: QuantRecipe | None, path: str, K: int, N: int,
           axes, *, bias: bool = False, dtype=jnp.bfloat16):
    qspec = recipe.spec_for(path) if recipe is not None else None
    return qlinear.linear_specs(K, N, qspec, axes, bias=bias, dtype=dtype)


# Calibration capture: when enabled (and running EAGERLY with
# cfg.scan_layers=False), every linear's input activations are recorded per
# path in call order — GPTQ/AWQ/SmoothQuant read these (core/ptq.py).
_CAPTURE: dict | None = None
_CAPTURE_SAMPLES = 256


def start_capture() -> None:
    global _CAPTURE
    _CAPTURE = {}


def end_capture() -> dict:
    global _CAPTURE
    out, _CAPTURE = _CAPTURE, None
    return out or {}


def apply_linear(recipe: QuantRecipe | None, path: str,
                 params: dict, x: jax.Array, *,
                 mode: str | None = None) -> jax.Array:
    qspec = recipe.spec_for(path) if recipe is not None else None
    if _CAPTURE is not None and not isinstance(
            x, jax.core.Tracer):
        import numpy as np

        x2 = np.asarray(x, dtype=np.float32).reshape(-1, x.shape[-1])
        step = max(1, x2.shape[0] // _CAPTURE_SAMPLES)
        _CAPTURE.setdefault(path, []).append(x2[::step][:_CAPTURE_SAMPLES])
    # params may be stacked (scan): qlinear handles only per-layer; scan
    # bodies receive the already-sliced layer params, so shapes are 2D here.
    # ``mode`` is cfg.kernel_mode threaded from the model block; None defers
    # to the ambient default inside qlinear.
    return qlinear.linear_apply(params, x, qspec, mode=mode)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> dict:
    return {"g": S.ones((d,), ("embed",))}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * params["g"].astype(jnp.float32)
            ).astype(dt)


def layernorm_spec(d: int) -> dict:
    return {"g": S.ones((d,), ("embed",)), "b": S.zeros((d,), ("embed",))}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32)
            + params["b"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Spec stacking for scan-over-layers
# ---------------------------------------------------------------------------


def stack_specs(tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked leading dim (scanned layers) to every ParamSpec."""

    def one(s: S.ParamSpec) -> S.ParamSpec:
        return S.ParamSpec(
            (n, *s.shape), s.dtype, s.init,
            (axis_name, *s.logical_axes), s.init_scale,
        )

    return jax.tree.map(one, tree, is_leaf=S.is_spec)


def take_layer(stacked: Any, i) -> Any:
    """Slice layer i out of a stacked param tree (for unscanned access)."""
    return jax.tree.map(lambda a: a[i], stacked)
