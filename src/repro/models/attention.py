"""Attention variants: chunked-flash GQA/MQA, MLA (latent KV), cross-attn.

All softmax attention goes through :func:`flash_attention` — a lax.scan
online-softmax over KV chunks (and a map over Q chunks) so that 32k-token
prefill never materializes an S^2 score tensor. Supports causal masks,
sliding windows (recurrentgemma local attention) and int8-quantized KV
(beyond-paper QServe-inspired option).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import spec as S
from .common import apply_linear, linear, rmsnorm, rmsnorm_spec
from .config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2) f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (S, D/2) or (B, S, D/2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch/heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:  # (B, S, D/2)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(dt)


# ---------------------------------------------------------------------------
# Chunked flash attention (online softmax, scan over KV chunks)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softmax_scale: float | None = None,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(D))
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to chunk multiples (mask handles the tail)
    Sqp = -(-Sq // q_chunk) * q_chunk
    Skp = -(-Sk // kv_chunk) * kv_chunk
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    qg = q.reshape(B, Sqp, Hkv, G, D)
    nq, nk = Sqp // q_chunk, Skp // kv_chunk

    def q_block(qi):
        qch = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, 1)
        qch = qch.astype(jnp.float32) * scale
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, ki):
            m, den, acc = carry
            kch = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vch = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qch, kch.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = k_pos[None, :] < Sk  # padding mask
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            den_new = den * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vch.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, den_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32)
        (m, den, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                        jnp.arange(nk))
        out = acc / jnp.maximum(den, 1e-30)[..., None]
        return out  # (B, Hkv, G, q_chunk, Dv)

    if nq == 1:
        out = q_block(0)[:, :, :, None]  # (B,Hkv,G,1,qc,Dv)
    else:
        out = jax.lax.map(q_block, jnp.arange(nq))  # (nq,B,Hkv,G,qc,Dv)
        out = jnp.moveaxis(out, 0, 3)
    out = out.reshape(B, Hkv, G, Sqp, Dv).transpose(0, 3, 1, 2, 4)
    out = out.reshape(B, Sqp, Hq, Dv)[:, :Sq]
    return out.astype(v.dtype if v.dtype != jnp.int8 else jnp.bfloat16)


def decode_attention(
    q: jax.Array,       # (B, 1, Hq, D)
    k_cache: jax.Array, # (B, Smax, Hkv, D)   (may be int8)
    v_cache: jax.Array, # (B, Smax, Hkv, Dv)
    length: jax.Array,  # () int32 — valid prefix length (inclusive of new tok)
    *,
    window: int | None = None,
    softmax_scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, Smax, Hkv, 1) if int8 KV
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-step attention over a (possibly int8) KV cache."""
    B, Smax, Hkv, D = k_cache.shape
    Dv = v_cache.shape[-1]
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(D))
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kf,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(Smax)
    lens = jnp.reshape(jnp.asarray(length), (-1, 1))  # scalar or per-slot (B,)
    mask = pos[None, :] < lens
    if window is not None:
        mask &= pos[None, :] > lens - 1 - window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dv)


# ---------------------------------------------------------------------------
# KV-cache quantization helpers (int8 per-token-per-head absmax)
# ---------------------------------------------------------------------------


def quantize_kv(x: jax.Array):
    """(B, S, H, D) -> int8 codes + (B, S, H, 1) f32 scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


# ---------------------------------------------------------------------------
# GQA / MQA attention module
# ---------------------------------------------------------------------------


def gqa_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    dt = cfg.activation_dtype
    return {
        "q": linear(recipe, f"{base}/q", d, Hq * hd, ("embed", "heads_q"),
                    bias=cfg.qkv_bias, dtype=dt),
        "k": linear(recipe, f"{base}/k", d, Hkv * hd, ("embed", "heads_kv"),
                    bias=cfg.qkv_bias, dtype=dt),
        "v": linear(recipe, f"{base}/v", d, Hkv * hd, ("embed", "heads_kv"),
                    bias=cfg.qkv_bias, dtype=dt),
        "o": linear(recipe, f"{base}/o", Hq * hd, d, ("heads_q", "embed"),
                    dtype=dt),
    }


def gqa_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    hd, Hkv = cfg.head_dim, cfg.num_kv_heads
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": S.zeros((batch, max_seq, Hkv, hd),
                         ("cache_batch", "cache_seq", "heads_kv", None),
                         dtype=jnp.int8),
            "v": S.zeros((batch, max_seq, Hkv, hd),
                         ("cache_batch", "cache_seq", "heads_kv", None),
                         dtype=jnp.int8),
            "k_scale": S.zeros((batch, max_seq, Hkv, 1),
                               ("cache_batch", "cache_seq", "heads_kv", None),
                               dtype=jnp.float32),
            "v_scale": S.zeros((batch, max_seq, Hkv, 1),
                               ("cache_batch", "cache_seq", "heads_kv", None),
                               dtype=jnp.float32),
        }
    dt = cfg.activation_dtype
    return {
        "k": S.zeros((batch, max_seq, Hkv, hd),
                     ("cache_batch", "cache_seq", "heads_kv", None), dtype=dt),
        "v": S.zeros((batch, max_seq, Hkv, hd),
                     ("cache_batch", "cache_seq", "heads_kv", None), dtype=dt),
    }


def _is_vec_pos(pos) -> bool:
    return getattr(pos, "ndim", 0) == 1


def _cache_write(cache_arr: jax.Array, val: jax.Array, pos) -> jax.Array:
    """Write (B, S_new, ...) at offset ``pos`` — scalar offset (aligned
    batch) or per-slot (B,) vector (continuous batching; S_new must be 1)."""
    if _is_vec_pos(pos):
        b = jnp.arange(val.shape[0])
        return cache_arr.at[b, pos].set(val[:, 0].astype(cache_arr.dtype))
    return jax.lax.dynamic_update_slice_in_dim(
        cache_arr, val.astype(cache_arr.dtype), pos, axis=1)


def _store_kv(cfg: ModelConfig, cache: dict, k, v, pos) -> dict:
    """Write new k/v (B, S_new, Hkv, D) into the cache at offset pos."""
    new = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        for name, val in (("k", kq), ("v", vq), ("k_scale", ks),
                          ("v_scale", vs)):
            new[name] = _cache_write(cache[name], val, pos)
    else:
        for name, val in (("k", k), ("v", v)):
            new[name] = _cache_write(cache[name], val, pos)
    return new


def gqa_apply(
    params: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    recipe,
    base: str,
    *,
    mode: str = "train",           # train | prefill | decode
    cache: dict | None = None,
    pos=0,                         # int32 scalar: tokens already in cache
    window: int | None = None,
):
    B, Sq, d = x.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = apply_linear(recipe, f"{base}/q", params["q"], x).reshape(B, Sq, Hq, hd)
    k = apply_linear(recipe, f"{base}/k", params["k"], x).reshape(B, Sq, Hkv, hd)
    v = apply_linear(recipe, f"{base}/v", params["v"], x).reshape(B, Sq, Hkv, hd)

    if _is_vec_pos(pos):
        positions = pos[:, None] + jnp.arange(Sq)[None, :]  # (B, Sq)
    else:
        positions = pos + jnp.arange(Sq)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if mode == "decode":
        cache = _store_kv(cfg, cache, k, v, pos)
        out = decode_attention(
            q, cache["k"], cache["v"], pos + Sq, window=window,
            k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        ).astype(x.dtype)
    else:
        if cache is not None:  # prefill: also populate the cache
            cache = _store_kv(cfg, cache, k, v, pos)
        if cfg.attention_impl.startswith("pallas"):
            from repro.kernels.flash_attention import flash_attention_tpu

            out = flash_attention_tpu(
                q, k, v, causal=True, window=window,
                interpret=(cfg.attention_impl == "pallas_interpret"),
            ).astype(x.dtype)
        else:
            out = flash_attention(
                q, k, v, causal=True, window=window,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            ).astype(x.dtype)

    out = out.reshape(B, Sq, Hq * hd)
    y = apply_linear(recipe, f"{base}/o", params["o"], out)
    return y, cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention — DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    r, nd, vd = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    dt = cfg.activation_dtype
    out: dict = {}
    if cfg.q_lora_rank:
        out["q_down"] = linear(recipe, f"{base}/q_down", d, cfg.q_lora_rank,
                               ("embed", "q_lora"), dtype=dt)
        out["q_norm"] = rmsnorm_spec(cfg.q_lora_rank)
        q_in = cfg.q_lora_rank
    else:
        q_in = d
    out["q_up"] = linear(recipe, f"{base}/q_up", q_in, H * (nd + r),
                         ("q_lora", "heads_q"), dtype=dt)
    out["kv_down"] = linear(recipe, f"{base}/kv_down", d,
                            cfg.kv_lora_rank + r, ("embed", "kv_lora"),
                            dtype=dt)
    out["kv_norm"] = rmsnorm_spec(cfg.kv_lora_rank)
    out["k_up"] = linear(recipe, f"{base}/k_up", cfg.kv_lora_rank, H * nd,
                         ("kv_lora", "heads_q"), dtype=dt)
    out["v_up"] = linear(recipe, f"{base}/v_up", cfg.kv_lora_rank, H * vd,
                         ("kv_lora", "heads_q"), dtype=dt)
    out["o"] = linear(recipe, f"{base}/o", H * vd, d, ("heads_q", "embed"),
                      dtype=dt)
    return out


def mla_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """The latent cache: c_kv (+ rope'd shared key) — the whole point of MLA."""
    dt = cfg.activation_dtype
    return {
        "c_kv": S.zeros((batch, max_seq, cfg.kv_lora_rank),
                        ("cache_batch", "cache_seq", "kv_lora"), dtype=dt),
        "k_rope": S.zeros((batch, max_seq, cfg.qk_rope_dim),
                          ("cache_batch", "cache_seq", None), dtype=dt),
    }


def _mla_qkv(params, x, cfg: ModelConfig, recipe, base, positions):
    """Shared projections: returns per-head q (nope+rope) and latent (c, kr)."""
    B, Sq, _ = x.shape
    H = cfg.num_heads
    r, nd = cfg.qk_rope_dim, cfg.qk_nope_dim
    if cfg.q_lora_rank:
        cq = apply_linear(recipe, f"{base}/q_down", params["q_down"], x)
        cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
    else:
        cq = x
    q = apply_linear(recipe, f"{base}/q_up", params["q_up"], cq)
    q = q.reshape(B, Sq, H, nd + r)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    ckv = apply_linear(recipe, f"{base}/kv_down", params["kv_down"], x)
    c_kv, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, r, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    recipe,
    base: str,
    *,
    mode: str = "train",
    cache: dict | None = None,
    pos=0,
):
    B, Sq, d = x.shape
    H = cfg.num_heads
    r, nd, vd = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    if _is_vec_pos(pos):
        positions = pos[:, None] + jnp.arange(Sq)[None, :]
    else:
        positions = pos + jnp.arange(Sq)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(
        params, x, cfg, recipe, base, positions)

    if cache is not None:  # store the LATENT cache
        cache = dict(cache)
        cache["c_kv"] = _cache_write(cache["c_kv"], c_kv, pos)
        cache["k_rope"] = _cache_write(cache["k_rope"], k_rope, pos)

    if mode == "decode":
        # Absorbed-matrix decode: never materialize per-head K/V.
        # score = (W_uk^T q_nope) . c_kv + q_rope . k_rope
        k_up = _dense_weight(params["k_up"], recipe, f"{base}/k_up",
                             cfg.kv_lora_rank, cfg.activation_dtype)
        v_up = _dense_weight(params["v_up"], recipe, f"{base}/v_up",
                             cfg.kv_lora_rank, cfg.activation_dtype)
        k_up = k_up.reshape(cfg.kv_lora_rank, H, nd)
        v_up = v_up.reshape(cfg.kv_lora_rank, H, vd)
        q_eff = jnp.einsum("bqhn,chn->bqhc", q_nope.astype(jnp.float32),
                           k_up.astype(jnp.float32))
        ckv_f = cache["c_kv"].astype(jnp.float32)
        kr_f = cache["k_rope"].astype(jnp.float32)
        s = (jnp.einsum("bqhc,bsc->bhqs", q_eff, ckv_f)
             + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32), kr_f))
        s = s / math.sqrt(nd + r)
        lens = jnp.reshape(jnp.asarray(pos) + Sq, (-1, 1))
        mask = jnp.arange(cache["c_kv"].shape[1])[None, :] < lens
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx_c = jnp.einsum("bhqs,bsc->bqhc", p, ckv_f)
        out = jnp.einsum("bqhc,chv->bqhv", ctx_c,
                         v_up.astype(jnp.float32)).astype(x.dtype)
    else:
        # prefill/train: materialize per-head K/V from the latent, flash-attend
        k_nope = apply_linear(recipe, f"{base}/k_up", params["k_up"], c_kv)
        k_nope = k_nope.reshape(B, Sq, H, nd)
        v = apply_linear(recipe, f"{base}/v_up", params["v_up"], c_kv)
        v = v.reshape(B, Sq, H, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Sq, H, r))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(
            q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            softmax_scale=1.0 / math.sqrt(nd + r),
        ).astype(x.dtype)

    out = out.reshape(B, Sq, H * vd)
    y = apply_linear(recipe, f"{base}/o", params["o"], out)
    return y, cache


def _dense_weight(params: dict, recipe, path: str, K: int, dtype):
    """Reconstruct a bf16 weight from (possibly quantized) linear params for
    einsum-style uses (MLA weight absorption). Weight-only-equivalent."""
    qspec = recipe.spec_for(path) if recipe is not None else None
    if qspec is None:
        return params["w"]
    from repro.core.qlinear import _unpack

    wq = _unpack(params, qspec, K)
    N = wq.shape[1]
    gs = qspec.group_size if qspec.group_size > 0 else K
    G = K // gs
    scale = params["scale"].astype(jnp.float32)
    if "alpha" in params:
        scale = scale / params["alpha"]
    w = wq.reshape(G, gs, N).astype(jnp.float32) * scale[:, None, :]
    return w.reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Cross attention (VLM image layers / whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    dt = cfg.activation_dtype
    return {
        "q": linear(recipe, f"{base}/q", d, Hq * hd, ("embed", "heads_q"),
                    dtype=dt),
        "k": linear(recipe, f"{base}/k", d, Hkv * hd, ("embed", "heads_kv"),
                    dtype=dt),
        "v": linear(recipe, f"{base}/v", d, Hkv * hd, ("embed", "heads_kv"),
                    dtype=dt),
        "o": linear(recipe, f"{base}/o", Hq * hd, d, ("heads_q", "embed"),
                    dtype=dt),
        "q_norm": rmsnorm_spec(d),
    }


def cross_attn_cache_specs(cfg: ModelConfig, batch: int, mem_len: int) -> dict:
    hd, Hkv = cfg.head_dim, cfg.num_kv_heads
    dt = cfg.activation_dtype
    return {
        "k": S.zeros((batch, mem_len, Hkv, hd),
                     ("cache_batch", None, "heads_kv", None), dtype=dt),
        "v": S.zeros((batch, mem_len, Hkv, hd),
                     ("cache_batch", None, "heads_kv", None), dtype=dt),
    }


def cross_attn_apply(
    params: dict,
    x: jax.Array,          # (B, Sq, d)
    cfg: ModelConfig,
    recipe,
    base: str,
    *,
    memory: jax.Array | None = None,  # (B, Sm, d) — prefill/train
    cache: dict | None = None,
    mode: str = "train",
):
    B, Sq, d = x.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    xq = rmsnorm(params["q_norm"], x, cfg.norm_eps)
    q = apply_linear(recipe, f"{base}/q", params["q"], xq)
    q = q.reshape(B, Sq, Hq, hd)
    if mode == "decode":
        k = cache["k"].astype(x.dtype)
        v = cache["v"].astype(x.dtype)
    else:
        k = apply_linear(recipe, f"{base}/k", params["k"], memory)
        v = apply_linear(recipe, f"{base}/v", params["v"], memory)
        Sm = memory.shape[1]
        k = k.reshape(B, Sm, Hkv, hd)
        v = v.reshape(B, Sm, Hkv, hd)
        if cache is not None:
            cache = {"k": k.astype(cache["k"].dtype),
                     "v": v.astype(cache["v"].dtype)}
    out = flash_attention(q, k, v, causal=False, q_chunk=cfg.q_chunk,
                          kv_chunk=cfg.kv_chunk).astype(x.dtype)
    out = out.reshape(B, Sq, Hq * hd)
    y = apply_linear(recipe, f"{base}/o", params["o"], out)
    return y, cache
