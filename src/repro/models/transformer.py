"""Decoder-only transformer LM covering dense / MoE / VLM families.

Layers are grouped into (unrolled prefix + repeating pattern x R); the
repeating pattern is scanned with ``lax.scan`` over stacked params so 100-
layer configs lower to a compact HLO, with optional per-block remat for
training. Heterogeneous stacks (DeepSeek's leading dense layer, the vision
model's every-5th cross-attention layer) fall out of the same mechanism.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.nn import spec as S
from . import attention as A
from .common import rmsnorm, rmsnorm_spec, stack_specs
from .config import ModelConfig
from .mlp import mlp_apply, mlp_specs
from .moe import moe_apply, moe_specs


# ---------------------------------------------------------------------------
# Layer layout: kinds, prefix/pattern split
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[str]:
    L = cfg.num_layers
    if cfg.family == "vlm" and cfg.cross_attn_every:
        return ["cross" if (i + 1) % cfg.cross_attn_every == 0 else "self"
                for i in range(L)]
    if cfg.num_experts:
        return ["self"] * cfg.first_dense_layers + \
               ["moe"] * (L - cfg.first_dense_layers)
    return ["self"] * L


def split_layers(kinds: list[str], max_period: int = 8):
    """-> (prefix_kinds, pattern_kinds, repeats) minimizing prefix then
    period, so scan covers as much as possible."""
    n = len(kinds)
    for p in range(0, n):
        rest = kinds[p:]
        for period in range(1, max_period + 1):
            if len(rest) % period:
                continue
            pat = rest[:period]
            if pat * (len(rest) // period) == rest:
                return kinds[:p], pat, len(rest) // period
    return kinds, [], 0


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    if cfg.attention == "mla":
        return A.mla_specs(cfg, recipe, base)
    return A.gqa_specs(cfg, recipe, base)


def _block_specs(cfg: ModelConfig, recipe, kind: str, base: str) -> dict:
    d = cfg.d_model
    out: dict = {"ln1": rmsnorm_spec(d), "ln2": rmsnorm_spec(d)}
    if kind == "cross":
        out["attn"] = A.cross_attn_specs(cfg, recipe, f"{base}/xattn")
        out["mlp"] = mlp_specs(cfg, recipe, f"{base}/mlp")
        out["gate_attn"] = S.zeros((), ())
        out["gate_mlp"] = S.zeros((), ())
    else:
        out["attn"] = _attn_specs(cfg, recipe, f"{base}/attn")
        if kind == "moe":
            out["mlp"] = moe_specs(cfg, recipe, f"{base}/mlp")
        else:
            out["mlp"] = mlp_specs(cfg, recipe, f"{base}/mlp")
    return out


def _block_cache_specs(cfg: ModelConfig, kind: str, batch: int,
                       max_seq: int) -> dict:
    if kind == "cross":
        mem = cfg.num_image_tokens or cfg.encoder_seq
        return A.cross_attn_cache_specs(cfg, batch, mem)
    if cfg.attention == "mla":
        return A.mla_cache_specs(cfg, batch, max_seq)
    return A.gqa_cache_specs(cfg, batch, max_seq)


def _block_apply(params, x, cfg: ModelConfig, recipe, kind: str, base: str,
                 *, mode, cache, pos, memory):
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind == "cross":
        h, cache = A.cross_attn_apply(
            params["attn"], h, cfg, recipe, f"{base}/xattn",
            memory=memory, cache=cache, mode=mode)
        x = x + jnp.tanh(params["gate_attn"]).astype(x.dtype) * h
        h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
        h2 = mlp_apply(params["mlp"], h2, cfg, recipe, f"{base}/mlp")
        x = x + jnp.tanh(params["gate_mlp"]).astype(x.dtype) * h2
        return x, cache, aux
    if cfg.attention == "mla":
        h, cache = A.mla_apply(params["attn"], h, cfg, recipe,
                               f"{base}/attn", mode=mode, cache=cache,
                               pos=pos)
    else:
        h, cache = A.gqa_apply(params["attn"], h, cfg, recipe,
                               f"{base}/attn", mode=mode, cache=cache,
                               pos=pos)
    x = x + h
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        h2, aux = moe_apply(params["mlp"], h2, cfg, recipe, f"{base}/mlp")
    else:
        h2 = mlp_apply(params["mlp"], h2, cfg, recipe, f"{base}/mlp")
    x = x + h2
    return x, cache, aux


# ---------------------------------------------------------------------------
# Whole-model specs
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig, recipe=None) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    dt = cfg.activation_dtype
    prefix, pattern, R = split_layers(layer_kinds(cfg))
    specs: dict = {
        "embed": S.w((V, d), ("vocab", "embed"), dtype=dt, init="embed"),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        specs["head"] = {"w": S.w((d, V), ("embed", "vocab"), dtype=dt)}
    if prefix:
        specs["prefix"] = {
            str(i): _block_specs(cfg, recipe, k, f"prefix/{i}")
            for i, k in enumerate(prefix)
        }
    if R:
        pat = {f"s{j}": _block_specs(cfg, recipe, k, f"blocks/s{j}")
               for j, k in enumerate(pattern)}
        specs["blocks"] = stack_specs(pat, R)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    prefix, pattern, R = split_layers(layer_kinds(cfg))
    out: dict = {}
    if prefix:
        out["prefix"] = {
            str(i): _block_cache_specs(cfg, k, batch, max_seq)
            for i, k in enumerate(prefix)
        }
    if R:
        pat = {f"s{j}": _block_cache_specs(cfg, k, batch, max_seq)
               for j, k in enumerate(pattern)}
        out["blocks"] = stack_specs(pat, R, axis_name="layers")
    return out


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def apply(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S) int32
    *,
    recipe=None,
    mode: str = "train",
    cache: dict | None = None,
    pos=0,
    memory: jax.Array | None = None,  # (B, Sm, d) image/frame embeddings
):
    """Returns (logits f32 (B, S, V), new_cache, aux_loss).

    ``cfg.kernel_mode`` (when set) selects the qlinear backend for every
    quantized linear in the forward. It is established here, inside the
    (possibly jitted) function body, so retraces re-apply it.
    """
    if cfg.kernel_mode:
        from repro.core import qlinear

        with qlinear.kernel_mode(cfg.kernel_mode):
            return _apply(params, cfg, tokens, recipe=recipe, mode=mode,
                          cache=cache, pos=pos, memory=memory)
    return _apply(params, cfg, tokens, recipe=recipe, mode=mode,
                  cache=cache, pos=pos, memory=memory)


def _apply(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    recipe=None,
    mode: str = "train",
    cache: dict | None = None,
    pos=0,
    memory: jax.Array | None = None,
):
    prefix, pattern, R = split_layers(layer_kinds(cfg))
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {} if cache is not None else None

    if prefix:
        if cache is not None:
            new_cache["prefix"] = {}
        for i, kind in enumerate(prefix):
            c = cache["prefix"][str(i)] if cache is not None else None
            x, c, a = _block_apply(
                params["prefix"][str(i)], x, cfg, recipe, kind,
                f"prefix/{i}", mode=mode, cache=c, pos=pos, memory=memory)
            aux = aux + a
            if cache is not None:
                new_cache["prefix"][str(i)] = c

    if R:
        def body(carry, inp):
            xc, auxc = carry
            if cache is not None:
                p_l, c_l = inp
            else:
                p_l, c_l = inp, None
            c_out = {}
            for j, kind in enumerate(pattern):
                cj = c_l[f"s{j}"] if c_l is not None else None
                xc, cj, a = _block_apply(
                    p_l[f"s{j}"], xc, cfg, recipe, kind, f"blocks/s{j}",
                    mode=mode, cache=cj, pos=pos, memory=memory)
                auxc = auxc + a
                if cache is not None:
                    c_out[f"s{j}"] = cj
            return (xc, auxc), (c_out if cache is not None else None)

        if not cfg.scan_layers:
            # unrolled python loop — required for eager calibration capture
            from .common import take_layer

            for r in range(R):
                p_r = take_layer(params["blocks"], r)
                c_r = take_layer(cache["blocks"], r) if cache is not None \
                    else None
                (x, aux), _ = body((x, aux), (p_r, c_r)
                                   if cache is not None else p_r)
        else:
            if cfg.remat and mode == "train":
                body = jax.checkpoint(body, prevent_cse=False)
            xs = (params["blocks"], cache["blocks"]) if cache is not None \
                else params["blocks"]
            (x, aux), scanned_cache = jax.lax.scan(body, (x, aux), xs)
            if cache is not None:
                new_cache["blocks"] = scanned_cache

    if mode == "prefill":
        # serving semantics: only the last position's logits are needed —
        # slicing before the head avoids a (B, S, V) logits tensor.
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].astype(
            jnp.float32).T
    else:
        logits = x.astype(jnp.float32) @ params["head"]["w"].astype(
            jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, new_cache, aux
