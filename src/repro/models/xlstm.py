"""xLSTM (sLSTM + mLSTM blocks) — attention-free recurrent LM.

Faithful to arXiv:2405.04517 structure at the block level:
  * mLSTM: matrix memory C (dh x dh per head), exponential input gate,
    sigmoid forget gate, stabilizer state m; q/k from a causal-conv path.
  * sLSTM: scalar memory with per-head block-diagonal recurrent weights,
    exponential gating + stabilizer; followed by a gated FFN (factor 4/3).
  * blocks alternate mLSTM : sLSTM at 7:1 (``slstm_every``).

Temporal mixing runs as a ``lax.scan`` over time (exact recurrence). The
recurrent state is O(1) in sequence length — this is why xlstm-1.3b runs
the ``long_500k`` cell that full-attention archs must skip. Decode carries
{C, n, m} / {c, n, m, h} per block in the cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import spec as S
from .common import apply_linear, linear, rmsnorm, rmsnorm_spec, stack_specs
from .config import ModelConfig


def _d_inner(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.mlstm_proj_factor)


def _dh(cfg: ModelConfig) -> int:
    return _d_inner(cfg) // cfg.num_heads


# ---------------------------------------------------------------------------
# Causal depthwise conv (width 4)
# ---------------------------------------------------------------------------


def conv_specs(d: int, width: int) -> dict:
    return {"w": S.w((width, d), (None, "embed")),
            "b": S.zeros((d,), ("embed",))}


def causal_conv(params: dict, x: jax.Array, *, state: jax.Array | None = None):
    """x (B, S, d). state (B, width-1, d) carries the rolling window for
    decode. Returns (y, new_state)."""
    w = params["w"].astype(jnp.float32)
    width, d = w.shape
    xf = x.astype(jnp.float32)
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, d), jnp.float32)
    else:
        pad = state.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)  # (B, S+w-1, d)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    y = y + params["b"].astype(jnp.float32)
    new_state = xp[:, -(width - 1):, :]
    return y.astype(x.dtype), new_state.astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, di = cfg.d_model, _d_inner(cfg)
    H = cfg.num_heads
    dt = cfg.activation_dtype
    return {
        "ln": rmsnorm_spec(d),
        "up": linear(recipe, f"{base}/up", d, 2 * di, ("embed", "mlp"),
                     dtype=dt),
        "conv": conv_specs(di, cfg.conv_width),
        "q": linear(recipe, f"{base}/q", di, di, ("mlp", "heads_q"), dtype=dt),
        "k": linear(recipe, f"{base}/k", di, di, ("mlp", "heads_q"), dtype=dt),
        "v": linear(recipe, f"{base}/v", di, di, ("mlp", "heads_q"), dtype=dt),
        "if_gate": {"w": S.w((di, 2 * H), ("mlp", None), scale=0.3),
                    "b": S.zeros((2 * H,), (None,))},
        "out_norm": rmsnorm_spec(di),
        "down": linear(recipe, f"{base}/down", di, d, ("mlp", "embed"),
                       dtype=dt),
    }


def mlstm_state_specs(cfg: ModelConfig, batch: int) -> dict:
    H, dh = cfg.num_heads, _dh(cfg)
    di = _d_inner(cfg)
    return {
        "C": S.zeros((batch, H, dh, dh), ("cache_batch", "heads_q", None, None),
                     dtype=jnp.float32),
        "n": S.zeros((batch, H, dh), ("cache_batch", "heads_q", None),
                     dtype=jnp.float32),
        "m": S.zeros((batch, H), ("cache_batch", "heads_q"),
                     dtype=jnp.float32),
        "conv": S.zeros((batch, cfg.conv_width - 1, di),
                        ("cache_batch", None, "mlp"),
                        dtype=cfg.activation_dtype),
    }


def _mlstm_cell(state, qkvif):
    """One timestep of the stabilized mLSTM recurrence.

    state: C (B,H,dh,dh), n (B,H,dh), m (B,H)
    qkvif: q,k,v (B,H,dh); i_raw, f_raw (B,H)
    """
    C, n, m = state
    q, k, v, i_raw, f_raw = qkvif
    log_f = jax.nn.log_sigmoid(f_raw)  # (B,H)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :])  # (B,H,dh,dh)
    n_new = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C_new, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q))
    # stabilized denominator: max(|n.q|, exp(-m)) keeps the (C, n, m)
    # representation scale-invariant (paper eq. 26) — so a zero-initialized
    # decode state is exactly equivalent to the training init.
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_chunked(q, k, v, i_raw, f_raw, C0, n0, m0, chunk: int):
    """Chunkwise-PARALLEL mLSTM (beyond-paper §Perf optimization).

    Mathematically identical to scanning `_mlstm_cell` over time (tested
    allclose): the stabilizer admits the closed form
        m_t = F_t + max(m_0, cummax_{s<=t}(li_s - F_s)),
    F_t = cumsum(log sigmoid(f_raw)), so intra-chunk outputs become a
    decay-masked attention matmul and only a LIGHT scan over S/chunk
    summaries remains — sequential depth drops 32768 -> 128 for the
    prefill_32k cell (see EXPERIMENTS.md §Perf).

    q,k,v: (B,S,H,dh) f32; i_raw,f_raw: (B,S,H) f32.
    Returns (h (B,S,H,dh), (C,n,m) final state).
    """
    B, S, H, dh = q.shape
    c = min(chunk, S)
    nc = S // c
    assert S % c == 0, (S, c)

    def reshape_c(t):
        return jnp.moveaxis(t.reshape(B, nc, c, *t.shape[2:]), 1, 0)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
    lf = jax.nn.log_sigmoid(reshape_c(f_raw))       # (nc,B,c,H)
    li = reshape_c(i_raw)
    F = jnp.cumsum(lf, axis=2)                      # F_t
    run_max = jax.lax.cummax(li - F, axis=2)        # max_{s<=t}(li_s - F_s)
    mask = jnp.tril(jnp.ones((c, c), bool))

    def chunk_body(carry, inp):
        C_in, n_in, m_in = carry                # (B,H,dh,dh),(B,H,dh),(B,H)
        qb, kb, vb, Fb, lib, rmb = inp          # (B,c,H,dh) / (B,c,H)
        m_t = Fb + jnp.maximum(m_in[:, None, :], rmb)       # (B,c,H)
        g_in = jnp.exp(Fb + m_in[:, None, :] - m_t)         # (B,c,H)
        num_in = jnp.einsum("bhvk,bchk->bchv", C_in, qb)
        den_in = jnp.einsum("bhk,bchk->bch", n_in, qb)
        # intra-chunk: D[t,s] = exp(F_t - F_s + li_s - m_t), s <= t
        logD = (Fb[:, :, None, :] - Fb[:, None, :, :]
                + lib[:, None, :, :] - m_t[:, :, None, :])  # (B,t,s,H)
        D = jnp.where(mask[None, :, :, None], jnp.exp(logD), 0.0)
        scores = jnp.einsum("bthk,bshk->btsh", qb, kb) * D
        num = jnp.einsum("btsh,bshv->bthv", scores, vb) \
            + g_in[..., None] * num_in
        den = jnp.sum(scores, axis=2) + g_in * den_in       # (B,c,H)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # chunk-final summaries (t = c)
        m_c = m_t[:, -1, :]
        decay_s = jnp.exp(Fb[:, -1, None, :] - Fb + lib
                          - m_c[:, None, :])                # (B,c,H)
        carry_g = jnp.exp(Fb[:, -1, :] + m_in - m_c)        # (B,H)
        C_new = (carry_g[..., None, None] * C_in
                 + jnp.einsum("bsh,bshv,bshk->bhvk", decay_s, vb, kb))
        n_new = (carry_g[..., None] * n_in
                 + jnp.einsum("bsh,bshk->bhk", decay_s, kb))
        return (C_new, n_new, m_c), h

    (C, n, m), hs = jax.lax.scan(chunk_body, (C0, n0, m0),
                                 (qc, kc, vc, F, li, run_max))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)
    return h, (C, n, m)


def mlstm_apply(params, x, cfg: ModelConfig, recipe, base: str, *,
                state: dict | None = None):
    """x (B,S,d) -> (y, new_state). state=None => fresh zeros (training)."""
    B, Sq, d = x.shape
    H, dh, di = cfg.num_heads, _dh(cfg), _d_inner(cfg)
    h_in = rmsnorm(params["ln"], x, cfg.norm_eps)
    up = apply_linear(recipe, f"{base}/up", params["up"], h_in)
    xm, z = up[..., :di], up[..., di:]
    conv_state = state["conv"] if state is not None else None
    xc, conv_new = causal_conv(params["conv"], xm, state=conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = apply_linear(recipe, f"{base}/q", params["q"], xc)
    k = apply_linear(recipe, f"{base}/k", params["k"], xc) / math.sqrt(dh)
    v = apply_linear(recipe, f"{base}/v", params["v"], xm)
    gates = (xm.astype(jnp.float32) @ params["if_gate"]["w"]
             + params["if_gate"]["b"])  # (B,S,2H)
    i_raw, f_raw = gates[..., :H], gates[..., H:]

    def reshape_heads(t):
        return t.reshape(B, Sq, H, dh).astype(jnp.float32)

    q, k, v = reshape_heads(q), reshape_heads(k), reshape_heads(v)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = (state["C"].astype(jnp.float32),
                      state["n"].astype(jnp.float32),
                      state["m"].astype(jnp.float32))

    if cfg.mlstm_impl == "chunked" and Sq > 1:
        hseq, (C, n, m) = _mlstm_chunked(
            q, k, v, i_raw.astype(jnp.float32),
            f_raw.astype(jnp.float32), C0, n0, m0, cfg.chunk_size)
        h = hseq
    else:
        def step(carry, t_in):
            return _mlstm_cell(carry, t_in)

        xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
              jnp.moveaxis(v, 1, 0), jnp.moveaxis(i_raw, 1, 0),
              jnp.moveaxis(f_raw, 1, 0))
        (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
        h = jnp.moveaxis(hs, 0, 1)
    h = h.reshape(B, Sq, di)  # (B,S,di)
    h = rmsnorm(params["out_norm"], h.astype(x.dtype), cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = apply_linear(recipe, f"{base}/down", params["down"], h)
    new_state = None
    if state is not None:
        new_state = {"C": C, "n": n, "m": m, "conv": conv_new}
    return x + y, new_state


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    dt = cfg.activation_dtype
    ff = int(d * 4 / 3)
    ff = -(-ff // 128) * 128  # 128-multiple so group-128 quant applies
    return {
        "ln": rmsnorm_spec(d),
        "wx": linear(recipe, f"{base}/wx", d, 4 * d, ("embed", "mlp"),
                     dtype=dt),
        # block-diagonal recurrent weights: (H, dh, 4*dh)
        "r": S.w((H, dh, 4 * dh), ("heads_q", None, None), scale=1.0),
        "out_norm": rmsnorm_spec(d),
        "ff_gate": linear(recipe, f"{base}/ff_gate", d, ff,
                          ("embed", "mlp"), dtype=dt),
        "ff_up": linear(recipe, f"{base}/ff_up", d, ff, ("embed", "mlp"),
                        dtype=dt),
        "ff_down": linear(recipe, f"{base}/ff_down", ff, d,
                          ("mlp", "embed"), dtype=dt),
    }


def slstm_state_specs(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.num_heads
    dh = cfg.d_model // H
    ax = ("cache_batch", "heads_q", None)
    return {
        "c": S.zeros((batch, H, dh), ax, dtype=jnp.float32),
        "n": S.zeros((batch, H, dh), ax, dtype=jnp.float32),
        "m": S.zeros((batch, H, dh), ax, dtype=jnp.float32),
        "h": S.zeros((batch, H, dh), ax, dtype=jnp.float32),
    }


def slstm_apply(params, x, cfg: ModelConfig, recipe, base: str, *,
                state: dict | None = None):
    B, Sq, d = x.shape
    H = cfg.num_heads
    dh = d // H
    xi = rmsnorm(params["ln"], x, cfg.norm_eps)
    pre = apply_linear(recipe, f"{base}/wx", params["wx"], xi)  # (B,S,4d)
    pre = pre.reshape(B, Sq, H, 4 * dh).astype(jnp.float32)

    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        st = (z, z, jnp.zeros((B, H, dh), jnp.float32), z)
    else:
        st = (state["c"].astype(jnp.float32), state["n"].astype(jnp.float32),
              state["m"].astype(jnp.float32), state["h"].astype(jnp.float32))

    r = params["r"].astype(jnp.float32)

    def step(carry, pre_t):  # pre_t (B,H,4dh)
        c, n, m, h = carry
        rec = jnp.einsum("bhd,hdk->bhk", h, r)  # (B,H,4dh)
        g = pre_t + rec
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h_last), hs = jax.lax.scan(step, st, jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, Sq, d).astype(x.dtype)
    h = rmsnorm(params["out_norm"], h, cfg.norm_eps)
    x = x + h
    # gated FFN (factor 4/3)
    g = apply_linear(recipe, f"{base}/ff_gate", params["ff_gate"], x)
    u = apply_linear(recipe, f"{base}/ff_up", params["ff_up"], x)
    ff = apply_linear(recipe, f"{base}/ff_down", params["ff_down"],
                      jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u)
    new_state = None
    if state is not None:
        new_state = {"c": c, "n": n, "m": m, "h": h_last}
    return x + ff, new_state


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[str]:
    return ["slstm" if (i + 1) % cfg.slstm_every == 0 else "mlstm"
            for i in range(cfg.num_layers)]


def _split(cfg: ModelConfig):
    from .transformer import split_layers

    return split_layers(layer_kinds(cfg))


def param_specs(cfg: ModelConfig, recipe=None) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    dt = cfg.activation_dtype
    prefix, pattern, R = _split(cfg)
    specs: dict = {
        "embed": S.w((V, d), ("vocab", "embed"), dtype=dt, init="embed"),
        "final_norm": rmsnorm_spec(d),
        "head": {"w": S.w((d, V), ("embed", "vocab"), dtype=dt)},
    }

    def block_specs(kind, base):
        if kind == "slstm":
            return slstm_specs(cfg, recipe, base)
        return mlstm_specs(cfg, recipe, base)

    if prefix:
        specs["prefix"] = {str(i): block_specs(k, f"prefix/{i}")
                           for i, k in enumerate(prefix)}
    if R:
        pat = {f"s{j}": block_specs(k, f"blocks/s{j}")
               for j, k in enumerate(pattern)}
        specs["blocks"] = stack_specs(pat, R)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """max_seq unused — recurrent state is O(1). Kept for API parity."""
    prefix, pattern, R = _split(cfg)

    def block_state(kind):
        if kind == "slstm":
            return slstm_state_specs(cfg, batch)
        return mlstm_state_specs(cfg, batch)

    out: dict = {}
    if prefix:
        out["prefix"] = {str(i): block_state(k)
                         for i, k in enumerate(prefix)}
    if R:
        pat = {f"s{j}": block_state(k) for j, k in enumerate(pattern)}
        out["blocks"] = stack_specs(pat, R)
    return out


def apply(params, cfg: ModelConfig, tokens, *, recipe=None, mode="train",
          cache=None, pos=0, memory=None):
    prefix, pattern, R = _split(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    new_cache: dict | None = {} if cache is not None else None

    def block(p, xc, kind, base, st):
        if kind == "slstm":
            return slstm_apply(p, xc, cfg, recipe, base, state=st)
        return mlstm_apply(p, xc, cfg, recipe, base, state=st)

    if prefix:
        if cache is not None:
            new_cache["prefix"] = {}
        for i, kind in enumerate(prefix):
            st = cache["prefix"][str(i)] if cache is not None else None
            x, st = block(params["prefix"][str(i)], x, kind, f"prefix/{i}", st)
            if cache is not None:
                new_cache["prefix"][str(i)] = st

    if R:
        def body(xc, inp):
            if cache is not None:
                p_l, c_l = inp
            else:
                p_l, c_l = inp, None
            outs = {}
            for j, kind in enumerate(pattern):
                st = c_l[f"s{j}"] if c_l is not None else None
                xc, st = block(p_l[f"s{j}"], xc, kind, f"blocks/s{j}", st)
                if cache is not None:
                    outs[f"s{j}"] = st
            return xc, (outs if cache is not None else None)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        xs = (params["blocks"], cache["blocks"]) if cache is not None \
            else params["blocks"]
        x, scanned = jax.lax.scan(body, x, xs)
        if cache is not None:
            new_cache["blocks"] = scanned

    if mode == "prefill":
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["head"]["w"].astype(jnp.float32)
    return logits, new_cache, jnp.zeros((), jnp.float32)
