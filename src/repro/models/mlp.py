"""Gated MLPs (SwiGLU / GeGLU) with recipe-aware quantized linears."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_linear, linear
from .config import ModelConfig


def mlp_specs(cfg: ModelConfig, recipe, base: str, d_ff: int | None = None,
              activation: str = "silu") -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.activation_dtype
    return {
        "gate": linear(recipe, f"{base}/gate", d, f, ("embed", "mlp"), dtype=dt),
        "up": linear(recipe, f"{base}/up", d, f, ("embed", "mlp"), dtype=dt),
        "down": linear(recipe, f"{base}/down", f, d, ("mlp", "embed"), dtype=dt),
    }


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig, recipe,
              base: str, activation: str = "silu") -> jax.Array:
    g = apply_linear(recipe, f"{base}/gate", params["gate"], x)
    u = apply_linear(recipe, f"{base}/up", params["up"], x)
    h = _act(activation, g.astype(jnp.float32)).astype(x.dtype) * u
    return apply_linear(recipe, f"{base}/down", params["down"], h)
