"""Whisper-style encoder-decoder (audio backbone; conv frontend STUBBED).

Per the assignment, ``input_specs()`` provides precomputed frame embeddings
(B, S_enc, d) — the mel-spectrogram conv stem is a stub. The transformer
backbone is faithful: LayerNorm blocks, bidirectional encoder self-attn,
causal decoder self-attn + cross-attn to the encoder output, GELU MLPs,
sinusoidal encoder positions / learned decoder positions.

Decode caches: decoder self KV + cross KV (computed once at prefill).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import spec as S
from . import attention as A
from .common import apply_linear, layernorm, layernorm_spec, linear, \
    stack_specs
from .config import ModelConfig


def _attn_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    H = cfg.num_heads
    dt = cfg.activation_dtype
    return {
        "q": linear(recipe, f"{base}/q", d, H * hd, ("embed", "heads_q"),
                    bias=True, dtype=dt),
        "k": linear(recipe, f"{base}/k", d, H * hd, ("embed", "heads_kv"),
                    dtype=dt),
        "v": linear(recipe, f"{base}/v", d, H * hd, ("embed", "heads_kv"),
                    bias=True, dtype=dt),
        "o": linear(recipe, f"{base}/o", H * hd, d, ("heads_q", "embed"),
                    bias=True, dtype=dt),
    }


def _mlp_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.activation_dtype
    return {
        "up": linear(recipe, f"{base}/up", d, f, ("embed", "mlp"),
                     bias=True, dtype=dt),
        "down": linear(recipe, f"{base}/down", f, d, ("mlp", "embed"),
                       bias=True, dtype=dt),
    }


def _mlp_apply(p, x, cfg, recipe, base):
    h = apply_linear(recipe, f"{base}/up", p["up"], x)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return apply_linear(recipe, f"{base}/down", p["down"], h)


def _attend(p, xq, xkv, cfg: ModelConfig, recipe, base, *, causal,
            cache=None, pos=0, mode="train", cross=False):
    B, Sq, d = xq.shape
    hd, H = cfg.head_dim, cfg.num_heads
    q = apply_linear(recipe, f"{base}/q", p["q"], xq).reshape(B, Sq, H, hd)
    if cross and mode == "decode":
        k = cache["k"].astype(xq.dtype)
        v = cache["v"].astype(xq.dtype)
        out = A.flash_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    else:
        Skv = xkv.shape[1]
        k = apply_linear(recipe, f"{base}/k", p["k"], xkv)
        v = apply_linear(recipe, f"{base}/v", p["v"], xkv)
        k = k.reshape(B, Skv, H, hd)
        v = v.reshape(B, Skv, H, hd)
        if cross:
            if cache is not None:
                cache = {"k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype)}
            out = A.flash_attention(q, k, v, causal=False,
                                    q_chunk=cfg.q_chunk,
                                    kv_chunk=cfg.kv_chunk)
        elif mode == "decode":
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            out = A.decode_attention(q, cache["k"], cache["v"], pos + Sq)
        else:
            if cache is not None:
                cache = dict(cache)
                cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
                cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            out = A.flash_attention(q, k, v, causal=causal,
                                    q_chunk=cfg.q_chunk,
                                    kv_chunk=cfg.kv_chunk)
    out = out.astype(xq.dtype).reshape(B, Sq, H * hd)
    y = apply_linear(recipe, f"{base}/o", p["o"], out)
    return y, cache


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _enc_block_specs(cfg, recipe, base):
    return {"ln1": layernorm_spec(cfg.d_model),
            "attn": _attn_specs(cfg, recipe, f"{base}/attn"),
            "ln2": layernorm_spec(cfg.d_model),
            "mlp": _mlp_specs(cfg, recipe, f"{base}/mlp")}


def _dec_block_specs(cfg, recipe, base):
    return {"ln1": layernorm_spec(cfg.d_model),
            "self": _attn_specs(cfg, recipe, f"{base}/self"),
            "ln_x": layernorm_spec(cfg.d_model),
            "cross": _attn_specs(cfg, recipe, f"{base}/cross"),
            "ln2": layernorm_spec(cfg.d_model),
            "mlp": _mlp_specs(cfg, recipe, f"{base}/mlp")}


def param_specs(cfg: ModelConfig, recipe=None) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    dt = cfg.activation_dtype
    ne = cfg.num_encoder_layers or cfg.num_layers
    nd = cfg.num_layers
    return {
        "enc": {
            "blocks": stack_specs(
                _enc_block_specs(cfg, recipe, "enc/blocks"), ne),
            "final_ln": layernorm_spec(d),
        },
        "dec": {
            "embed": S.w((V, d), ("vocab", "embed"), dtype=dt, init="embed"),
            "pos": S.w((cfg.max_positions, d), (None, "embed"), dtype=dt,
                       scale=0.02),
            "blocks": stack_specs(
                _dec_block_specs(cfg, recipe, "dec/blocks"), nd),
            "final_ln": layernorm_spec(d),
        },
    }


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    d, hd, H = cfg.d_model, cfg.head_dim, cfg.num_heads
    dt = cfg.activation_dtype
    nd = cfg.num_layers
    ax = ("cache_batch", "cache_seq", "heads_kv", None)
    axm = ("cache_batch", None, "heads_kv", None)
    blk = {
        "self": {
            "k": S.zeros((batch, max_seq, H, hd), ax, dtype=dt),
            "v": S.zeros((batch, max_seq, H, hd), ax, dtype=dt),
        },
        "cross": {
            "k": S.zeros((batch, cfg.encoder_seq, H, hd), axm, dtype=dt),
            "v": S.zeros((batch, cfg.encoder_seq, H, hd), axm, dtype=dt),
        },
    }
    return {"blocks": stack_specs(blk, nd)}


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _sinusoid(S_: int, d: int):
    pos = jnp.arange(S_, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ModelConfig, frames: jax.Array, recipe=None):
    """frames: (B, S_enc, d) stub embeddings -> encoder output (B, S_enc, d)."""
    B, Se, d = frames.shape
    x = frames.astype(cfg.activation_dtype)
    x = x + _sinusoid(Se, d).astype(x.dtype)[None]
    enc = params["enc"]

    def body(xc, p_l):
        h = layernorm(p_l["ln1"], xc, cfg.norm_eps)
        h, _ = _attend(p_l["attn"], h, h, cfg, recipe, "enc/blocks/attn",
                       causal=False)
        xc = xc + h
        h = layernorm(p_l["ln2"], xc, cfg.norm_eps)
        xc = xc + _mlp_apply(p_l["mlp"], h, cfg, recipe, "enc/blocks/mlp")
        return xc, None

    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return layernorm(enc["final_ln"], x, cfg.norm_eps)


def apply(params, cfg: ModelConfig, tokens, *, recipe=None, mode="train",
          cache=None, pos=0, memory=None):
    """memory = frame embeddings (train/prefill); decode uses cross cache."""
    B, Sq = tokens.shape
    dec = params["dec"]
    enc_out = None
    if mode != "decode":
        enc_out = encode(params, cfg, memory, recipe)
    x = dec["embed"].astype(cfg.activation_dtype)[tokens]
    posn = pos + jnp.arange(Sq)
    x = x + dec["pos"].astype(x.dtype)[posn][None]

    def body(carry, inp):
        xc = carry
        if cache is not None:
            p_l, c_l = inp
        else:
            p_l, c_l = inp, None
        h = layernorm(p_l["ln1"], xc, cfg.norm_eps)
        h, c_self = _attend(p_l["self"], h, h, cfg, recipe,
                            "dec/blocks/self", causal=True,
                            cache=(c_l["self"] if c_l else None),
                            pos=pos, mode=mode)
        xc = xc + h
        h = layernorm(p_l["ln_x"], xc, cfg.norm_eps)
        h, c_cross = _attend(p_l["cross"], h, enc_out, cfg, recipe,
                             "dec/blocks/cross", causal=False,
                             cache=(c_l["cross"] if c_l else None),
                             mode=mode, cross=True)
        xc = xc + h
        h = layernorm(p_l["ln2"], xc, cfg.norm_eps)
        xc = xc + _mlp_apply(p_l["mlp"], h, cfg, recipe, "dec/blocks/mlp")
        out_c = {"self": c_self, "cross": c_cross} if c_l is not None else None
        return xc, out_c

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (dec["blocks"], cache["blocks"]) if cache is not None \
        else dec["blocks"]
    x, scanned = jax.lax.scan(body, x, xs)
    new_cache = {"blocks": scanned} if cache is not None else None
    if mode == "prefill":
        x = x[:, -1:]
    x = layernorm(dec["final_ln"], x, cfg.norm_eps)
    logits = x.astype(jnp.float32) @ dec["embed"].astype(jnp.float32).T
    return logits, new_cache, jnp.zeros((), jnp.float32)
