"""Unified model configuration covering every assigned architecture family.

One frozen dataclass; families toggle feature blocks. Exact per-arch values
live in ``repro/configs/<arch>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "vlm", "ssm", "hybrid", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # -- attention ----------------------------------------------------------
    attention: Literal["gqa", "mla", "none"] = "gqa"
    qkv_bias: bool = False  # qwen2 uses bias on QKV
    rope_theta: float = 10_000.0
    q_chunk: int = 512      # flash-attention chunk sizes
    kv_chunk: int = 1024
    # "jax" = chunked-scan flash (always available; dry-run path);
    # "pallas" = fused TPU kernel (kernels/flash_attention.py);
    # "pallas_interpret" = same kernel, CPU-validated
    attention_impl: str = "jax"

    # -- MLA (minicpm3 / deepseek-v2) ----------------------------------------
    q_lora_rank: int = 0     # 0 -> direct q projection
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # -- MoE ------------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0
    first_dense_layers: int = 0     # leading dense blocks (deepseek-v2: 1)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    dispatch_groups: int = 1        # set = data-axis size under pjit
    moe_int8_dispatch: bool = False  # compress the dispatch all-to-all

    # -- VLM (llama-3.2-vision) ----------------------------------------------
    cross_attn_every: int = 0       # every k-th layer is cross-attention
    num_image_tokens: int = 0

    # -- hybrid (recurrentgemma / griffin) ------------------------------------
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    window: int = 2048                   # local-attention window
    conv_width: int = 4
    lru_c: float = 8.0

    # -- xlstm -----------------------------------------------------------------
    slstm_every: int = 8            # every k-th block is sLSTM (7:1 ratio)
    mlstm_proj_factor: float = 2.0
    chunk_size: int = 256           # mLSTM chunkwise-parallel chunk
    mlstm_impl: str = "scan"        # "scan" (exact recurrence) | "chunked"

    # -- encoder-decoder (whisper) ---------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500         # stub frame-embedding count
    max_positions: int = 32768      # learned-pos table (enc-dec decoder)

    # -- norms / embeddings -----------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # -- execution ---------------------------------------------------------------
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # or "int8" (quantized KV, beyond-paper)
    scan_layers: bool = True
    remat: bool = True
    # Which qlinear backend quantized layers run: "reference" (pure jnp),
    # "pallas", "pallas_interpret"; None inherits the ambient default
    # (qlinear.current_kernel_mode()). The serving engine sets this from
    # ServeConfig.kernel_mode so its jitted decode drives the kernels.
    kernel_mode: str | None = None

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_rep(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count_estimate(self) -> int:
        """Rough dense-equivalent parameter count (embeddings included)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        if self.attention == "mla":
            attn = (self.q_lora_rank or d) * self.num_heads * (
                self.qk_nope_dim + self.qk_rope_dim) + d * (
                self.kv_lora_rank + self.qk_rope_dim) + self.kv_lora_rank * (
                self.num_heads * (self.qk_nope_dim + self.v_head_dim)) + (
                self.num_heads * self.v_head_dim * d)
            if self.q_lora_rank:
                attn += d * self.q_lora_rank
        else:
            attn = d * (self.num_heads * hd) * 2 + d * (
                self.num_kv_heads * hd) * 2
        if self.num_experts:
            ffn = 3 * d * self.moe_d_ff * (
                self.num_experts + self.num_shared_experts)
        else:
            ffn = 3 * d * self.d_ff
        return L * (attn + ffn) + 2 * V * d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts) —
        the N in MODEL_FLOPS = 6*N_active*D."""
        if not self.num_experts:
            return self.param_count_estimate()
        full = self.param_count_estimate()
        all_ffn = self.num_layers * 3 * self.d_model * self.moe_d_ff * (
            self.num_experts + self.num_shared_experts)
        act_ffn = self.num_layers * 3 * self.d_model * self.moe_d_ff * (
            self.top_k + self.num_shared_experts)
        return full - all_ffn + act_ffn
