"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Pattern (arXiv:2402.19427): temporal-mixing blocks cycle
(recurrent, recurrent, local-attention) — the 1:2 attention:recurrence
ratio — each followed by a GeGLU MLP block.

RG-LRU:  r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
         a_t = exp(-c * softplus(Lambda) * r_t)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
A *linear* recurrence -> ``jax.lax.associative_scan`` for train/prefill
(log-depth), O(1) state for decode. Local attention uses a ring-buffer KV
cache of exactly ``window`` slots, so the ``long_500k`` decode cell carries
O(window + d_rnn) state, not O(S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import spec as S
from . import attention as A
from .common import apply_linear, linear, rmsnorm, rmsnorm_spec, stack_specs
from .config import ModelConfig
from .xlstm import causal_conv, conv_specs


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------


def rglru_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d = cfg.d_model
    dr = d  # d_rnn = d_model (Griffin)
    dt = cfg.activation_dtype
    return {
        "ln": rmsnorm_spec(d),
        "gate_proj": linear(recipe, f"{base}/gate_proj", d, dr,
                            ("embed", "mlp"), dtype=dt),
        "x_proj": linear(recipe, f"{base}/x_proj", d, dr, ("embed", "mlp"),
                         dtype=dt),
        "conv": conv_specs(dr, cfg.conv_width),
        "lru": {
            "lam": S.w((dr,), ("mlp",), init="ones"),  # softplus(lam) decay
            "wa": S.w((dr, dr), ("mlp", "mlp2"), scale=0.5),
            "ba": S.zeros((dr,), ("mlp",)),
            "wi": S.w((dr, dr), ("mlp", "mlp2"), scale=0.5),
            "bi": S.zeros((dr,), ("mlp",)),
        },
        "out_proj": linear(recipe, f"{base}/out_proj", dr, d,
                           ("mlp", "embed"), dtype=dt),
    }


def rglru_state_specs(cfg: ModelConfig, batch: int) -> dict:
    dr = cfg.d_model
    return {
        "h": S.zeros((batch, dr), ("cache_batch", "mlp"), dtype=jnp.float32),
        "conv": S.zeros((batch, cfg.conv_width - 1, dr),
                        ("cache_batch", None, "mlp"),
                        dtype=cfg.activation_dtype),
    }


def _lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None):
    """h_t = a_t h_{t-1} + b_t over axis 1 via associative_scan (f32)."""
    if h0 is not None:
        # fold initial state into the first step: b_0 += a_0 * h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(params, x, cfg: ModelConfig, recipe, base: str, *,
                state: dict | None = None):
    B, Sq, d = x.shape
    xi = rmsnorm(params["ln"], x, cfg.norm_eps)
    gate = jax.nn.gelu(
        apply_linear(recipe, f"{base}/gate_proj", params["gate_proj"],
                     xi).astype(jnp.float32))
    xr = apply_linear(recipe, f"{base}/x_proj", params["x_proj"], xi)
    conv_state = state["conv"] if state is not None else None
    xr, conv_new = causal_conv(params["conv"], xr, state=conv_state)
    lru = params["lru"]
    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ lru["wa"].astype(jnp.float32)
                       + lru["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ lru["wi"].astype(jnp.float32)
                       + lru["bi"].astype(jnp.float32))
    log_a = -cfg.lru_c * jax.nn.softplus(
        lru["lam"].astype(jnp.float32)) * r  # (B,S,dr), <= 0
    a = jnp.exp(log_a)
    # sqrt(1-a^2) input normalization (Griffin eq. 5)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    h0 = state["h"].astype(jnp.float32) if state is not None else None
    h = _lru_scan(a, b, h0)  # (B,S,dr)
    y = (h * gate).astype(x.dtype)
    y = apply_linear(recipe, f"{base}/out_proj", params["out_proj"], y)
    new_state = None
    if state is not None:
        new_state = {"h": h[:, -1, :], "conv": conv_new}
    return x + y, new_state


# ---------------------------------------------------------------------------
# Local attention with ring-buffer KV cache
# ---------------------------------------------------------------------------


def local_attn_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    return {"ln": rmsnorm_spec(cfg.d_model),
            "attn": A.gqa_specs(cfg, recipe, f"{base}/attn")}


def local_attn_state_specs(cfg: ModelConfig, batch: int) -> dict:
    hd, Hkv, W = cfg.head_dim, cfg.num_kv_heads, cfg.window
    dt = cfg.activation_dtype
    return {
        "k": S.zeros((batch, W, Hkv, hd),
                     ("cache_batch", "cache_seq", "heads_kv", None), dtype=dt),
        "v": S.zeros((batch, W, Hkv, hd),
                     ("cache_batch", "cache_seq", "heads_kv", None), dtype=dt),
    }


def local_attn_apply(params, x, cfg: ModelConfig, recipe, base: str, *,
                     state: dict | None = None, pos=0, mode="train"):
    B, Sq, d = x.shape
    hd, Hq, Hkv, W = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads, cfg.window
    xi = rmsnorm(params["ln"], x, cfg.norm_eps)
    p = params["attn"]
    ab = f"{base}/attn"
    q = apply_linear(recipe, f"{ab}/q", p["q"], xi).reshape(B, Sq, Hq, hd)
    k = apply_linear(recipe, f"{ab}/k", p["k"], xi).reshape(B, Sq, Hkv, hd)
    v = apply_linear(recipe, f"{ab}/v", p["v"], xi).reshape(B, Sq, Hkv, hd)
    positions = pos + jnp.arange(Sq)
    cos, sin = A.rope_cos_sin(positions, hd, cfg.rope_theta)
    q = A.apply_rope(q, cos, sin)
    k = A.apply_rope(k, cos, sin)

    if mode == "decode":
        # ring-buffer write at slot pos % W
        slot = jnp.mod(pos, W)
        kc = jax.lax.dynamic_update_slice_in_dim(
            state["k"], k.astype(state["k"].dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            state["v"], v.astype(state["v"].dtype), slot, axis=1)
        state = {"k": kc, "v": vc}
        # slot s holds absolute position p_s = pos - ((pos - s) mod W)
        s_idx = jnp.arange(W)
        p_s = pos - jnp.mod(pos - s_idx, W)
        valid = p_s >= 0  # all within-window by construction
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        qg = q.reshape(B, Hkv, Hq // Hkv, hd).astype(jnp.float32)
        qg = qg / jnp.sqrt(jnp.float32(hd))
        s = jnp.einsum("bhgd,bshd->bhgs", qg, kf)
        s = jnp.where(valid[None, None, None], s, A.NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgs,bshd->bhgd", pr, vf)
        out = out.reshape(B, 1, Hq, hd).astype(x.dtype)
    else:
        out = A.flash_attention(
            q, k, v, causal=True, window=W,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk).astype(x.dtype)
        if state is not None:  # prefill: keep last W tokens, ring layout
            kc, vc = state["k"], state["v"]
            take = min(W, Sq)
            last_pos = pos + Sq - take + jnp.arange(take)
            slots = jnp.mod(last_pos, W)

            def put(c, val):
                return c.at[:, slots].set(
                    val[:, -take:].astype(c.dtype))

            state = {"k": put(kc, k), "v": put(vc, v)}
    out = out.reshape(B, Sq, Hq * hd)
    y = apply_linear(recipe, f"{ab}/o", p["o"], out)
    return x + y, state


# ---------------------------------------------------------------------------
# MLP (GeGLU) block
# ---------------------------------------------------------------------------


def mlp_block_specs(cfg: ModelConfig, recipe, base: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.activation_dtype
    return {
        "ln": rmsnorm_spec(d),
        "gate": linear(recipe, f"{base}/gate", d, f, ("embed", "mlp"),
                       dtype=dt),
        "up": linear(recipe, f"{base}/up", d, f, ("embed", "mlp"), dtype=dt),
        "down": linear(recipe, f"{base}/down", f, d, ("mlp", "embed"),
                       dtype=dt),
    }


def mlp_block_apply(params, x, cfg, recipe, base):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    g = apply_linear(recipe, f"{base}/gate", params["gate"], h)
    u = apply_linear(recipe, f"{base}/up", params["up"], h)
    y = apply_linear(recipe, f"{base}/down", params["down"],
                     jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u)
    return x + y


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[str]:
    pat = list(cfg.block_pattern) or ["rec", "rec", "attn"]
    kinds = []
    i = 0
    while len(kinds) < cfg.num_layers:
        kinds.append(pat[i % len(pat)])
        i += 1
    return kinds


def _split(cfg: ModelConfig):
    from .transformer import split_layers

    kinds = layer_kinds(cfg)
    # prefer scanning full patterns; leftover head becomes the prefix
    pat_len = len(list(cfg.block_pattern) or ["rec", "rec", "attn"])
    rem = cfg.num_layers % pat_len
    if rem:
        return kinds[:rem], kinds[rem:rem + pat_len], \
            (cfg.num_layers - rem) // pat_len
    return split_layers(kinds, max_period=pat_len)


def _block_specs(cfg, recipe, kind, base):
    if kind == "rec":
        return {"mix": rglru_specs(cfg, recipe, f"{base}/rglru"),
                "mlp": mlp_block_specs(cfg, recipe, f"{base}/mlp")}
    return {"mix": local_attn_specs(cfg, recipe, f"{base}/lattn"),
            "mlp": mlp_block_specs(cfg, recipe, f"{base}/mlp")}


def _block_state_specs(cfg, kind, batch):
    if kind == "rec":
        return rglru_state_specs(cfg, batch)
    return local_attn_state_specs(cfg, batch)


def _block_apply(p, x, cfg, recipe, kind, base, *, st, pos, mode):
    if kind == "rec":
        x, st = rglru_apply(p["mix"], x, cfg, recipe, f"{base}/rglru",
                            state=st)
    else:
        x, st = local_attn_apply(p["mix"], x, cfg, recipe, f"{base}/lattn",
                                 state=st, pos=pos, mode=mode)
    x = mlp_block_apply(p["mlp"], x, cfg, recipe, f"{base}/mlp")
    return x, st


def param_specs(cfg: ModelConfig, recipe=None) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    dt = cfg.activation_dtype
    prefix, pattern, R = _split(cfg)
    specs: dict = {
        # std 1/sqrt(d): the runtime x*sqrt(d) scaling (Gemma convention)
        # then yields unit-RMS streams; std-1.0 init would saturate the
        # logit softcap at init (tanh -> zero gradient).
        "embed": S.w((V, d), ("vocab", "embed"), dtype=dt, init="embed",
                     scale=d ** -0.5),
        "final_norm": rmsnorm_spec(d),
    }
    if prefix:
        specs["prefix"] = {str(i): _block_specs(cfg, recipe, k, f"prefix/{i}")
                           for i, k in enumerate(prefix)}
    if R:
        pat = {f"s{j}": _block_specs(cfg, recipe, k, f"blocks/s{j}")
               for j, k in enumerate(pattern)}
        specs["blocks"] = stack_specs(pat, R)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    prefix, pattern, R = _split(cfg)
    out: dict = {}
    if prefix:
        out["prefix"] = {str(i): _block_state_specs(cfg, k, batch)
                         for i, k in enumerate(prefix)}
    if R:
        pat = {f"s{j}": _block_state_specs(cfg, k, batch)
               for j, k in enumerate(pattern)}
        out["blocks"] = stack_specs(pat, R)
    return out


def apply(params, cfg: ModelConfig, tokens, *, recipe=None, mode="train",
          cache=None, pos=0, memory=None):
    prefix, pattern, R = _split(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    # RecurrentGemma scales embeddings by sqrt(d)
    x = x * jnp.asarray(jnp.sqrt(jnp.float32(cfg.d_model)), x.dtype)
    new_cache: dict | None = {} if cache is not None else None

    if prefix:
        if cache is not None:
            new_cache["prefix"] = {}
        for i, kind in enumerate(prefix):
            st = cache["prefix"][str(i)] if cache is not None else None
            x, st = _block_apply(params["prefix"][str(i)], x, cfg, recipe,
                                 kind, f"prefix/{i}", st=st, pos=pos,
                                 mode=mode)
            if cache is not None:
                new_cache["prefix"][str(i)] = st

    if R:
        def body(xc, inp):
            if cache is not None:
                p_l, c_l = inp
            else:
                p_l, c_l = inp, None
            outs = {}
            for j, kind in enumerate(pattern):
                st = c_l[f"s{j}"] if c_l is not None else None
                xc, st = _block_apply(p_l[f"s{j}"], xc, cfg, recipe, kind,
                                      f"blocks/s{j}", st=st, pos=pos,
                                      mode=mode)
                if cache is not None:
                    outs[f"s{j}"] = st
            return xc, (outs if cache is not None else None)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        xs = (params["blocks"], cache["blocks"]) if cache is not None \
            else params["blocks"]
        x, scanned = jax.lax.scan(body, x, xs)
        if cache is not None:
            new_cache["blocks"] = scanned

    if mode == "prefill":
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["embed"].astype(jnp.float32).T
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, new_cache, jnp.zeros((), jnp.float32)
