"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks at 1:7 [arXiv:2405.04517; unverified]. Attention-free;
O(1)-state decode => runs the long_500k cell."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=8, mlstm_proj_factor=2.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        num_layers=4, d_model=256, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=512, slstm_every=4,
    )


register_arch("xlstm-1.3b", full, smoke)
