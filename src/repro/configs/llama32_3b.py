"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2; unverified]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=128256, head_dim=128,
        rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=64,
        q_chunk=16, kv_chunk=16,
    )


register_arch("llama3.2-3b", full, smoke)
