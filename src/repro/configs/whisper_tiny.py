"""whisper-tiny [audio]: 4L (enc+dec) d_model=384 6H d_ff=1536
vocab=51865 — enc-dec with conv frontend STUB (input_specs provides frame
embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
        d_ff=1536, vocab_size=51865, head_dim=64,
        is_encoder_decoder=True, num_encoder_layers=4, encoder_seq=1500,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32,
        is_encoder_decoder=True, num_encoder_layers=2, encoder_seq=24,
        q_chunk=16, kv_chunk=16,
    )


register_arch("whisper-tiny", full, smoke)
