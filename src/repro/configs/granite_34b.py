"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch code model [arXiv:2405.04324; hf]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab_size=49152, head_dim=128,
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=1,
        d_ff=512, vocab_size=512, head_dim=64,
        q_chunk=16, kv_chunk=16,
    )


register_arch("granite-34b", full, smoke)
