"""minicpm3-4b [dense, MLA]: 62L d_model=2560 40H d_ff=6400 vocab=73448
— multi-head latent attention [hf:openbmb/MiniCPM3-4B]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        attention="mla", q_lora_rank=768, kv_lora_rank=256,
        qk_rope_dim=32, qk_nope_dim=64, v_head_dim=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512,
        attention="mla", q_lora_rank=128, kv_lora_rank=128,
        qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
        q_chunk=16, kv_chunk=16,
    )


register_arch("minicpm3-4b", full, smoke)
