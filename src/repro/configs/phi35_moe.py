"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8)
expert d_ff=6400 vocab=32064, MoE 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=6400, vocab_size=32064, head_dim=128,
        num_experts=16, top_k=2, moe_d_ff=6400,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=64,
        num_experts=4, top_k=2, moe_d_ff=256,
        q_chunk=16, kv_chunk=16,
    )


register_arch("phi3.5-moe-42b-a6.6b", full, smoke)
