"""Assigned input-shape grid + per-(arch x shape) input specs.

Every shape cell maps to ShapeDtypeStruct stand-ins (NO allocation) for the
step function the cell lowers:
  * train_*   -> ``train_step``  : {tokens, labels} (+ modality stubs)
  * prefill_* -> ``prefill_step``: {tokens} + zero cache
  * decode_* / long_* -> ``serve_step``: {tokens (B,1)} + full cache + pos
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, "sub-quadratic (O(1)/O(window) decode state)"
        return False, (
            "full softmax attention: a 524288-token dense KV cache is "
            "architecturally quadratic in attention reads; skipped per "
            "assignment (see DESIGN.md §5)")
    return True, ""


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """Model inputs (NOT params/cache — those come from ParamSpec trees)."""
    B = shape.batch
    dt = cfg.activation_dtype
    out: dict = {}
    if shape.kind == "train":
        out["tokens"] = _tok((B, shape.seq))
        out["labels"] = _tok((B, shape.seq))
    elif shape.kind == "prefill":
        out["tokens"] = _tok((B, shape.seq))
    else:  # decode
        out["tokens"] = _tok((B, 1))
    # modality stubs (assignment: frontend is a stub)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), dt)
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dt)
    return out


def memory_arg(cfg: ModelConfig, inputs: dict):
    """Extract the modality-stub memory arg the model's apply expects."""
    return inputs.get("image_embeds", inputs.get("frames"))
