"""The paper's own model family: LLaMA-2-7B structure (dry-run scale) and
a ~110M trainable variant used by examples/quickstart.py + the
quantization benchmarks (Tables 1/3/4/7 reproductions)."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=11008, vocab_size=32000, head_dim=128,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b-smoke", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512, head_dim=64,
        q_chunk=16, kv_chunk=16,
    )


def tiny_lm() -> ModelConfig:
    """~100M llama-style LM, trainable on CPU for the paper benchmarks.

    All K dims (d_model=768, d_ff=2048) are multiples of 128 so every
    linear supports fine-grained group-128 quantization. f32 on CPU
    (bf16 is emulated and slow there)."""
    return ModelConfig(
        name="tiny-lm-100m", family="dense",
        num_layers=14, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=2048, vocab_size=512, head_dim=64, dtype="float32",
        q_chunk=64, kv_chunk=64, remat=False,
    )


register_arch("llama2-7b", full, smoke)


def bench_lm() -> ModelConfig:
    """~30M llama-style LM — the CPU-trainable model all quality
    benchmarks (Tables 1/3/4/7 reproductions) quantize and evaluate.
    K dims (512, 1536) are multiples of 128 for group-128 quantization."""
    return ModelConfig(
        name="bench-lm-30m", family="dense",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=1536, vocab_size=512, head_dim=64, dtype="float32",
        q_chunk=512, kv_chunk=512, remat=False,
    )
