"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke", family="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        qkv_bias=True, q_chunk=16, kv_chunk=16,
    )


register_arch("qwen2-72b", full, smoke)
