"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attention 1:2 (pattern
rec,rec,attn), window 2048 [arXiv:2402.19427; unverified].
O(window)-state decode => runs the long_500k cell."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab_size=256_000, head_dim=256,
        block_pattern=("rec", "rec", "attn"), window=2048,
        logit_softcap=30.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        num_layers=5, d_model=256, num_heads=4, num_kv_heads=1,
        d_ff=512, vocab_size=512, head_dim=64,
        block_pattern=("rec", "rec", "attn"), window=16,
        q_chunk=16, kv_chunk=16,
    )


register_arch("recurrentgemma-9b", full, smoke)
