"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256 — cross-attn image layers every 5th; the vision
frontend is a STUB (input_specs provides patch embeddings)
[hf:meta-llama/Llama-3.2-Vision; unverified]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256, head_dim=128,
        rope_theta=500_000.0,
        cross_attn_every=5, num_image_tokens=1600,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm",
        num_layers=5, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=64,
        cross_attn_every=5, num_image_tokens=16,
        q_chunk=16, kv_chunk=16,
    )


register_arch("llama-3.2-vision-90b", full, smoke)
