"""Architecture configs — one module per assigned arch (+ the paper's own).

Importing this package registers every arch with the model registry, so
``repro.models.registry.get_arch("<id>")`` / ``--arch <id>`` work.
"""
from . import (  # noqa: F401
    granite_34b,
    qwen2_72b,
    minicpm3_4b,
    llama32_3b,
    phi35_moe,
    mixtral_8x7b,
    deepseek_v2,
    llama32_vision_90b,
    xlstm_1b3,
    recurrentgemma_9b,
    whisper_tiny,
    paper_llama,
)
from .shapes import SHAPES, Shape, input_specs, shape_applicable  # noqa: F401
