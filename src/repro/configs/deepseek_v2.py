"""deepseek-v2-236b [moe, MLA]: 60L d_model=5120 128H, expert d_ff=1536,
vocab=102400, MoE 160 routed top-6 + 2 shared, MLA kv_lora=512
[arXiv:2405.04434]. First layer dense (d_ff 12288 = 8x expert dim)."""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=12288,  # the dense first layer; experts use moe_d_ff
        vocab_size=102400,
        attention="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        num_experts=160, num_shared_experts=2, top_k=6, moe_d_ff=1536,
        first_dense_layers=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke", family="moe",
        num_layers=3, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512,
        attention="mla", q_lora_rank=128, kv_lora_rank=128,
        qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
        num_experts=8, num_shared_experts=2, top_k=2, moe_d_ff=128,
        first_dense_layers=1, q_chunk=16, kv_chunk=16,
    )


register_arch("deepseek-v2-236b", full, smoke)
