"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) expert d_ff=14336
vocab=32000, MoE 8 experts top-2 — the paper's §5.5 end-to-end serving
subject (2.13x over FP16 with Integer Scale) [hf:mistralai/Mixtral-8x7B-v0.1].

The smoke shape keeps the 8-expert top-2 routing (the serving benchmark's
ragged decode skew depends on E > max_slots * top_k being possible) at
CPU-friendly dims; capacity_factor=4.0 = E/top_k makes per-group capacity
cover every routed token, so capacity drops can never occur and the engine
decode is bit-comparable to a full-forward oracle (tests/test_serving_moe).
"""
from repro.models.config import ModelConfig
from repro.models.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, head_dim=128,
        rope_theta=1e6,
        num_experts=8, top_k=2, moe_d_ff=14336,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=64,
        num_experts=8, top_k=2, moe_d_ff=256,
        capacity_factor=4.0,
        q_chunk=16, kv_chunk=16,
        dtype="float32", kv_cache_dtype="float32", remat=False,
    )


register_arch("mixtral-8x7b", full, smoke)
