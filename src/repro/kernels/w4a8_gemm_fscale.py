"""Baseline Pallas kernel: fine-grained W4A8 GEMM with FLOAT scales (Eq. 1).

Identical structure to ``w4a8_gemm.py`` except the inner loop — which is the
whole point. Per group it must
    1. convert the int32 MXU partial to f32            (I32->F32, VPU)
    2. FMA with the group's float scale into an f32 accumulator.
That is ``K/group`` converts + f32 FMAs per output tile (paper Fig. 2b,
Table 2 "Atom" column) versus ONE convert total for Integer Scale. Keeping
the two kernels diff-minimal isolates the paper's claim structurally; the
HLO op-count benchmark (benchmarks/kernel_latency.py) counts exactly this.

Also serves coarse-grained W4A8/W8A8 (group_size=-1): the single per-channel
scale is applied per K-block (mathematically identical since it is constant
across groups) — this is the OdysseyLLM-style baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .w4a8_gemm import _group_accumulate, _round_up, _snap_block


def _kernel(x_ref, wp_ref, s_ref, sa_ref, o_ref, facc_ref, *,
            nk: int, gs: int, groups_per_blk: int, w_bits: int,
            coarse: bool, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        facc_ref[...] = jnp.zeros_like(facc_ref)

    facc_ref[...] = _group_accumulate(
        x_ref[...], wp_ref[...], s_ref[...], facc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk, w_bits=w_bits,
        integer=False, coarse=coarse)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = (facc_ref[...] * sa_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "w_bits", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def fg_gemm_float_scale(
    xq: jax.Array,     # int8 (M, K)
    sa: jax.Array,     # f32 (M, 1)
    qvalue: jax.Array, # int8 (K/2, N) packed (w4) | (K, N) (w8)
    scale: jax.Array,  # f32 (K/g, N) fine | (1, N) coarse
    *,
    group_size: int = 128,  # -1 => coarse
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    M, K = xq.shape
    N = qvalue.shape[1]
    coarse = group_size <= 0
    gs = K if coarse else group_size
    bm = min(bm, _round_up(M, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), 1 if coarse else gs)
    if not coarse and bk % gs:
        bk = gs
    if coarse:
        gs = bk  # treat each K-block as one "group" with the constant scale
    nk = K // bk
    groups_per_blk = bk // gs

    Mp = _round_up(M, bm)
    if Mp != M:
        xq = jnp.pad(xq, ((0, Mp - M), (0, 0)))
        sa = jnp.pad(sa, ((0, Mp - M), (0, 0)))

    pack = 2 if w_bits == 4 else 1
    s_rows = 1 if coarse else groups_per_blk
    out = pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, coarse=coarse, out_dtype=out_dtype,
        ),
        grid=(Mp // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // pack, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((s_rows, bn),
                         (lambda i, j, k: (0, j)) if coarse
                         else (lambda i, j, k: (k, j))),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xq, qvalue, scale, sa)
    return out[:M]
