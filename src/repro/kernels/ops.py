"""Public jit'd wrappers over the Pallas kernels, plus scheme dispatch.

``qgemm`` is the single entry point used by ``repro.core.qlinear`` when the
kernel mode is "pallas" / "pallas_interpret": it routes a (QuantSpec,
operands) pair to the right kernel. On this CPU container only
``interpret=True`` executes; the BlockSpecs/grids are identical either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.recipe import QuantSpec

from .act_quant import act_quant
from .w4a8_gemm import fg_gemm_integer_scale
from .w4a8_gemm_fscale import fg_gemm_float_scale
from .w4a16_gemm import w4a16_gemm


def qgemm(
    x: jax.Array,         # (M, K) bf16/f32 activations
    qvalue: jax.Array,    # packed/int8 weights
    scale: jax.Array,     # int32 or f32 scales per scheme
    qspec: QuantSpec,
    *,
    alpha: float | None = None,
    interpret: bool = False,
    block: dict | None = None,
) -> jax.Array:
    """Quantized GEMM honoring ``qspec``; returns f32 (M, N)."""
    blk = block or {}
    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return w4a16_gemm(
            x, qvalue, scale, group_size=qspec.group_size,
            interpret=interpret, **blk,
        )

    xq, sa = act_quant(x, bits=qspec.a_bits, interpret=interpret)
    if qspec.scale_mode == "integer" and qspec.fine_grained:
        if alpha is None:
            alpha = float(qspec.amplifier) if isinstance(qspec.amplifier, int) \
                else 1024.0
        return fg_gemm_integer_scale(
            xq, sa, qvalue, scale,
            group_size=qspec.group_size, alpha=alpha, w_bits=qspec.w_bits,
            interpret=interpret, **blk,
        )
    return fg_gemm_float_scale(
        xq, sa, qvalue, scale,
        group_size=qspec.group_size, w_bits=qspec.w_bits,
        interpret=interpret, **blk,
    )


def qgemm_from_params(x, params: dict, qspec: QuantSpec, *, interpret=False,
                      block=None):
    """Convenience: dispatch straight from a qlinear param dict."""
    alpha = float(params["alpha"]) if "alpha" in params else None
    return qgemm(x, params["qvalue"], params["scale"], qspec,
                 alpha=alpha, interpret=interpret, block=block)
