"""Public jit'd wrappers over the Pallas kernels, plus scheme dispatch.

Call convention (v2)
--------------------
Two scheme-dispatched entry points, both consuming a qlinear param dict
directly (the dict ``qlinear.finish_quant`` / ``quantize_linear`` build:
``{"qvalue", "scale", "alpha"?}``) plus a :class:`BlockConfig`:

* ``qgemm(x, params, qspec, block=...)`` — dense (M, K) x (K, N), the
  entry point ``repro.core.qlinear.linear_apply`` uses under kernel mode
  "pallas" / "pallas_interpret".
* ``qgemm_grouped(x, params, qspec, row_counts=..., block=...)`` — the
  batched-expert MoE path: stacked (E, ...) operands, ONE fused ragged
  grouped kernel instead of a vmap over experts. ``row_counts`` (int32
  ``(E,)``, traced or concrete) lets the scalar-prefetch kernels skip
  m-tiles past each expert's routed token count — the continuous-batching
  decode path threads the live per-tick dispatch counts here.

``params["alpha"]`` (the integer-scale amplifier) may be a python float
(static, baked into the kernel epilogue) or a traced f32 scalar / (E,)
array (the per-layer / per-expert values stored by quantization) — traced
values are folded into the per-token activation scale, which is exact for
the power-of-two amplifiers Listing 1 produces. When absent, the fallback
is derived from ``qspec.amplifier``; heuristic amplifiers have no static
value and raise instead of silently rescaling by a wrong constant (the
stored alpha is what the PR-3 overflow certificates cover).

On this CPU container only ``BlockConfig(interpret=True)`` executes; the
BlockSpecs/grids are identical either way.

The v1 shims (``*_from_params``, positional ``qvalue, scale``,
``block=dict``, ``interpret=``) completed their one-release deprecation
window and are GONE; legacy forms now raise ``TypeError``. The kernel mode
itself ("reference" vs "pallas"[_interpret]) is NOT chosen here — callers
pass it explicitly to ``qlinear.linear_apply`` / ``grouped_linear_apply``
(see ``qlinear.kernel_mode`` for the script shim).

Telemetry (repro.obs)
---------------------
Every wrapper call increments ``qgemm_calls_total{scheme,kind,shape,
block}`` on the current registry. These are host/python-side counts: in
eager code they count executions; inside jit they count TRACES (a useful
retrace detector — steady-state serving holds them constant). Ragged
grouped calls with CONCRETE ``row_counts`` additionally record executed-
vs-total m-tiles (``qgemm_ragged_m_tiles_total{kind}``); traced counts
are skipped here and accounted at execution time by the serving engine's
routing sink instead. Per the repro.obs rule, nothing below reads or
writes metrics from inside a kernel body.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

import jax.numpy as jnp

from repro import obs
from repro.core.recipe import QuantSpec

from .act_quant import act_quant
from .moe_gemm import (fg_grouped_gemm_float_scale_ragged,
                       fg_grouped_gemm_integer_scale_ragged,
                       grouped_w4a16_gemm_ragged, ragged_tile_stats)
from .w4a8_gemm import fg_gemm_integer_scale
from .w4a8_gemm_fscale import fg_gemm_float_scale
from .w4a16_gemm import w4a16_gemm


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Kernel launch configuration: BlockSpec tile sizes + interpret mode.

    Divisibility is validated at construction (not at the first traced
    call): ``bm`` must be a multiple of 8 (the f32 sublane tile — the
    kernels snap it down to ``round_up(C, 8)`` for small decode batches),
    ``bn``/``bk`` multiples of 128 (the lane tile; ``bk`` must also hold
    whole quantization groups, which the kernels enforce against the
    qspec's ``group_size`` since that is a property of the weights, not of
    the launch). The defaults mirror every GEMM kernel's own defaults.
    """

    bm: int = 128
    bn: int = 256
    bk: int = 512
    interpret: bool = False

    def __post_init__(self):
        for name, val, mult in (("bm", self.bm, 8), ("bn", self.bn, 128),
                                ("bk", self.bk, 128)):
            if not isinstance(val, int) or val <= 0 or val % mult:
                raise ValueError(
                    f"BlockConfig.{name}={val!r}: must be a positive "
                    f"multiple of {mult} (BlockSpec tile divisibility)")

    def kernel_kwargs(self) -> dict:
        """Splat into the underlying Pallas wrapper call."""
        return {"bm": self.bm, "bn": self.bn, "bk": self.bk,
                "interpret": self.interpret}


#: Default launch config for CPU-validated kernels (tests/benchmarks).
INTERPRET = BlockConfig(interpret=True)


def _as_block(block) -> BlockConfig:
    if block is None:
        return BlockConfig()
    if isinstance(block, BlockConfig):
        return block
    raise TypeError(f"block must be BlockConfig or None, got "
                    f"{type(block).__name__} (the dict form was removed "
                    "with the v1 shims)")


def _resolve_alpha(alpha, qspec: QuantSpec):
    """Amplifier for the integer-scale epilogue.

    The stored per-layer/per-expert ``params["alpha"]`` always wins — it is
    the value the PR-3 overflow certificate covers (possibly capped below
    the qspec's request). Without it, a static integer ``qspec.amplifier``
    is an exact fallback; heuristic amplifiers resolve per layer at
    quantization time, so silently substituting a constant would rescale
    the output by an arbitrary factor AND bypass certification — raise.
    """
    if alpha is not None:
        return alpha
    if isinstance(qspec.amplifier, int):
        return float(qspec.amplifier)
    raise ValueError(
        f"qspec.amplifier={qspec.amplifier!r} is resolved per layer at "
        "quantization time; pass the stored per-layer alpha "
        "(params['alpha']) — no static fallback exists for heuristic "
        "amplifiers")


def _scheme_of(qspec: QuantSpec) -> str:
    if qspec.weight_only:
        return f"w{qspec.w_bits}a16"
    s = "is" if (qspec.scale_mode == "integer" and qspec.fine_grained) \
        else "fs"
    return f"w{qspec.w_bits}a{qspec.a_bits}-{s}"


def _concrete(x):
    """np array when x is host-concrete, None when traced."""
    try:
        return np.asarray(x)
    except Exception:  # TracerArrayConversionError and friends
        return None


def _record_call(scheme: str, kind: str, shape: tuple, blk: BlockConfig,
                 *, row_counts=None, capacity: int | None = None) -> None:
    reg = obs.current_registry()
    reg.counter(
        "qgemm_calls_total",
        "kernels.ops wrapper calls (trace-time under jit)",
        ("scheme", "kind", "shape", "block"),
    ).inc(scheme=scheme, kind=kind,
          shape="x".join(str(d) for d in shape),
          block=f"{blk.bm}x{blk.bn}x{blk.bk}")
    if row_counts is None:
        return
    rc = _concrete(row_counts)
    if rc is None:
        return  # traced: the engine routing sink accounts these
    st = ragged_tile_stats([int(v) for v in rc], int(capacity), blk.bm)
    tiles = reg.counter(
        "qgemm_ragged_m_tiles_total",
        "host-visible ragged grouped m-tiles: executed vs dense total",
        ("kind",))
    tiles.inc(st["ragged_m_tiles"], kind="executed")
    tiles.inc(st["dense_m_tiles"], kind="total")


def qgemm(
    x: jax.Array,         # (M, K) bf16/f32 activations
    params: dict,         # qlinear param dict: qvalue, scale, alpha?
    qspec: QuantSpec = None,
    *,
    block: BlockConfig | None = None,
) -> jax.Array:
    """Quantized GEMM honoring ``qspec``; returns f32 (M, N).

    Scheme dispatch (weight-only W4A16 / fine-grained integer scale /
    float scale) comes from the qspec; operands from the param dict.
    """
    if not isinstance(params, dict):
        raise TypeError(
            "qgemm takes the qlinear param dict as its second argument "
            "(the v1 positional qvalue/scale form was removed)")
    blk = _as_block(block)
    kw = blk.kernel_kwargs()
    N = params["qvalue"].shape[-1]
    _record_call(_scheme_of(qspec), "dense", (*x.shape, N), blk)

    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return w4a16_gemm(x, params["qvalue"], params["scale"],
                          group_size=qspec.group_size, **kw)

    xq, sa = act_quant(x, bits=qspec.a_bits, interpret=blk.interpret)
    if qspec.scale_mode == "integer" and qspec.fine_grained:
        a = _resolve_alpha(params.get("alpha"), qspec)
        if not isinstance(a, (int, float)):
            # traced per-layer amplifier: fold 1/alpha into sa (exact for
            # the power-of-two alphas the heuristic emits)
            sa = sa / jnp.asarray(a, jnp.float32)
            a = 1.0
        return fg_gemm_integer_scale(
            xq, sa, params["qvalue"], params["scale"],
            group_size=qspec.group_size, alpha=float(a),
            w_bits=qspec.w_bits, **kw)
    return fg_gemm_float_scale(
        xq, sa, params["qvalue"], params["scale"],
        group_size=qspec.group_size, w_bits=qspec.w_bits, **kw)


def qgemm_grouped(
    x: jax.Array,         # (E, C, K) bf16/f32 dispatch buffer
    params: dict,         # stacked per-expert param dict
    qspec: QuantSpec = None,
    *,
    row_counts=None,      # int32 (E,) routed rows per expert | None=all C
    block: BlockConfig | None = None,
) -> jax.Array:
    """Batched-expert quantized GEMM; returns f32 (E, C, N).

    Always routes through the ragged scalar-prefetch kernels
    (``kernels.moe_gemm``): activation quantization happens INSIDE the
    grouped kernel's first k-group pass (no dense ``act_quant`` sweep over
    the ``(E*C, K)`` buffer), and when ``row_counts`` is given, m-tiles
    entirely past an expert's routed row count are skipped. ``row_counts``
    is a data operand (traced under jit — the serving engine feeds the
    live per-tick dispatch counts without retracing). Rows at or past
    ``row_counts[e]`` must be zero-filled (the MoE dispatch guarantees
    this); ``row_counts=None`` treats every capacity slot as routed.
    """
    if not isinstance(params, dict):
        raise TypeError(
            "qgemm_grouped takes the stacked qlinear param dict as its "
            "second argument (the v1 positional qvalue/scale form was "
            "removed)")
    blk = _as_block(block)
    kw = blk.kernel_kwargs()
    N = params["qvalue"].shape[-1]
    _record_call(_scheme_of(qspec), "grouped", (*x.shape, N), blk,
                 row_counts=row_counts, capacity=x.shape[1])

    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return grouped_w4a16_gemm_ragged(
            x, row_counts, params["qvalue"], params["scale"],
            group_size=qspec.group_size, **kw)

    if qspec.scale_mode == "integer" and qspec.fine_grained:
        a = _resolve_alpha(params.get("alpha"), qspec)
        return fg_grouped_gemm_integer_scale_ragged(
            x, row_counts, params["qvalue"], params["scale"],
            group_size=qspec.group_size, alpha=a,
            a_bits=qspec.a_bits, w_bits=qspec.w_bits, **kw)
    return fg_grouped_gemm_float_scale_ragged(
        x, row_counts, params["qvalue"], params["scale"],
        group_size=qspec.group_size, a_bits=qspec.a_bits,
        w_bits=qspec.w_bits, **kw)
