"""Public jit'd wrappers over the Pallas kernels, plus scheme dispatch.

``qgemm`` is the single entry point used by ``repro.core.qlinear`` when the
kernel mode is "pallas" / "pallas_interpret": it routes a (QuantSpec,
operands) pair to the right kernel. ``qgemm_grouped`` is the batched-expert
analogue used by the MoE layer: stacked (E, ...) operands, one fused
grouped kernel instead of a vmap over experts. On this CPU container only
``interpret=True`` executes; the BlockSpecs/grids are identical either way.

``alpha`` (the integer-scale amplifier) may be a python float (static,
baked into the kernel epilogue) or a traced f32 scalar / (E,) array (the
per-layer / per-expert values stored in the param dict) — traced values are
folded into the per-token activation scale, which is exact for the
power-of-two amplifiers Listing 1 produces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.recipe import QuantSpec

from .act_quant import act_quant
from .moe_gemm import (fg_grouped_gemm_float_scale_ragged,
                       fg_grouped_gemm_integer_scale_ragged,
                       grouped_w4a16_gemm_ragged)
from .w4a8_gemm import fg_gemm_integer_scale
from .w4a8_gemm_fscale import fg_gemm_float_scale
from .w4a16_gemm import w4a16_gemm


def _default_alpha(qspec: QuantSpec) -> float:
    return float(qspec.amplifier) if isinstance(qspec.amplifier, int) \
        else 1024.0


def qgemm(
    x: jax.Array,         # (M, K) bf16/f32 activations
    qvalue: jax.Array,    # packed/int8 weights
    scale: jax.Array,     # int32 or f32 scales per scheme
    qspec: QuantSpec,
    *,
    alpha=None,           # float | traced f32 scalar | None
    interpret: bool = False,
    block: dict | None = None,
) -> jax.Array:
    """Quantized GEMM honoring ``qspec``; returns f32 (M, N)."""
    blk = block or {}
    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return w4a16_gemm(
            x, qvalue, scale, group_size=qspec.group_size,
            interpret=interpret, **blk,
        )

    xq, sa = act_quant(x, bits=qspec.a_bits, interpret=interpret)
    if qspec.scale_mode == "integer" and qspec.fine_grained:
        if alpha is None:
            alpha = _default_alpha(qspec)
        if not isinstance(alpha, (int, float)):
            # traced per-layer amplifier: fold 1/alpha into sa (exact for
            # the power-of-two alphas the heuristic emits)
            sa = sa / jnp.asarray(alpha, jnp.float32)
            alpha = 1.0
        return fg_gemm_integer_scale(
            xq, sa, qvalue, scale,
            group_size=qspec.group_size, alpha=float(alpha),
            w_bits=qspec.w_bits, interpret=interpret, **blk,
        )
    return fg_gemm_float_scale(
        xq, sa, qvalue, scale,
        group_size=qspec.group_size, w_bits=qspec.w_bits,
        interpret=interpret, **blk,
    )


def qgemm_from_params(x, params: dict, qspec: QuantSpec, *, interpret=False,
                      block=None):
    """Convenience: dispatch straight from a qlinear param dict.

    Passes the stored per-layer ``alpha`` through as a (possibly traced)
    array — NOT ``float()``-coerced, so this works under jit and heuristic
    amplifiers rescale by the layer's actual alpha.
    """
    return qgemm(x, params["qvalue"], params["scale"], qspec,
                 alpha=params.get("alpha"), interpret=interpret, block=block)


# ---------------------------------------------------------------------------
# Grouped (batched-expert) dispatch — the MoE fast path
# ---------------------------------------------------------------------------


def qgemm_grouped(
    x: jax.Array,         # (E, C, K) bf16/f32 dispatch buffer
    qvalue: jax.Array,    # (E, K/2, N) packed | (E, K, N) int8
    scale: jax.Array,     # (E, G, N) int32 or f32 per scheme
    qspec: QuantSpec,
    *,
    alpha=None,           # float | f32 (E,) per-expert amplifiers | None
    row_counts=None,      # int32 (E,) routed rows per expert | None=all C
    interpret: bool = False,
    block: dict | None = None,
) -> jax.Array:
    """Batched-expert quantized GEMM; returns f32 (E, C, N).

    Always routes through the ragged scalar-prefetch kernels
    (``kernels.moe_gemm``): activation quantization happens INSIDE the
    grouped kernel's first k-group pass (no dense ``act_quant`` sweep over
    the ``(E*C, K)`` buffer), and when ``row_counts`` is given, m-tiles
    entirely past an expert's routed row count are skipped. Rows at or past
    ``row_counts[e]`` must be zero-filled (the MoE dispatch guarantees
    this); ``row_counts=None`` treats every capacity slot as routed.
    """
    blk = block or {}
    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return grouped_w4a16_gemm_ragged(
            x, row_counts, qvalue, scale, group_size=qspec.group_size,
            interpret=interpret, **blk,
        )

    if qspec.scale_mode == "integer" and qspec.fine_grained:
        if alpha is None:
            alpha = _default_alpha(qspec)
        return fg_grouped_gemm_integer_scale_ragged(
            x, row_counts, qvalue, scale,
            group_size=qspec.group_size, alpha=alpha,
            a_bits=qspec.a_bits, w_bits=qspec.w_bits,
            interpret=interpret, **blk,
        )
    return fg_grouped_gemm_float_scale_ragged(
        x, row_counts, qvalue, scale,
        group_size=qspec.group_size, a_bits=qspec.a_bits,
        w_bits=qspec.w_bits, interpret=interpret, **blk,
    )


def qgemm_grouped_from_params(x, params: dict, qspec: QuantSpec, *,
                              row_counts=None, interpret=False, block=None):
    """Dispatch from a stacked (per-expert) qlinear param dict."""
    return qgemm_grouped(x, params["qvalue"], params["scale"], qspec,
                         alpha=params.get("alpha"), row_counts=row_counts,
                         interpret=interpret, block=block)
