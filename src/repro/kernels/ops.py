"""Public jit'd wrappers over the Pallas kernels, plus scheme dispatch.

Call convention (v2)
--------------------
Two scheme-dispatched entry points, both consuming a qlinear param dict
directly (the dict ``qlinear.finish_quant`` / ``quantize_linear`` build:
``{"qvalue", "scale", "alpha"?}``) plus a :class:`BlockConfig`:

* ``qgemm(x, params, qspec, block=...)`` — dense (M, K) x (K, N), the
  entry point ``repro.core.qlinear.linear_apply`` uses under kernel mode
  "pallas" / "pallas_interpret".
* ``qgemm_grouped(x, params, qspec, row_counts=..., block=...)`` — the
  batched-expert MoE path: stacked (E, ...) operands, ONE fused ragged
  grouped kernel instead of a vmap over experts. ``row_counts`` (int32
  ``(E,)``, traced or concrete) lets the scalar-prefetch kernels skip
  m-tiles past each expert's routed token count — the continuous-batching
  decode path threads the live per-tick dispatch counts here.

``params["alpha"]`` (the integer-scale amplifier) may be a python float
(static, baked into the kernel epilogue) or a traced f32 scalar / (E,)
array (the per-layer / per-expert values stored by quantization) — traced
values are folded into the per-token activation scale, which is exact for
the power-of-two amplifiers Listing 1 produces. When absent, the fallback
is derived from ``qspec.amplifier``; heuristic amplifiers have no static
value and raise instead of silently rescaling by a wrong constant (the
stored alpha is what the PR-3 overflow certificates cover).

On this CPU container only ``BlockConfig(interpret=True)`` executes; the
BlockSpecs/grids are identical either way.

Migration from the v1 API (one release of shims)
------------------------------------------------
==============================================  ===============================================
old                                             new
==============================================  ===============================================
``qgemm(x, qvalue, scale, qspec, alpha=a)``     ``qgemm(x, {"qvalue": qvalue, "scale": scale,``
                                                ``          "alpha": a}, qspec)``
``qgemm_from_params(x, params, qspec)``         ``qgemm(x, params, qspec)``
``qgemm_grouped(x, qvalue, scale, qspec)``      ``qgemm_grouped(x, params, qspec)``
``qgemm_grouped_from_params(x, params, ...)``   ``qgemm_grouped(x, params, ...)``
``interpret=True``                              ``block=BlockConfig(interpret=True)``
``block=dict(bm=.., bn=.., bk=..)``             ``block=BlockConfig(bm=.., bn=.., bk=..)``
==============================================  ===============================================

Every legacy form still works but emits a ``DeprecationWarning``; the
``*_from_params`` names and the dict/positional forms will be removed next
release. The kernel mode itself ("reference" vs "pallas"[_interpret]) is
NOT chosen here — callers pass it explicitly to ``qlinear.linear_apply`` /
``grouped_linear_apply`` (see ``qlinear.kernel_mode`` for the script shim).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.core.recipe import QuantSpec

from .act_quant import act_quant
from .moe_gemm import (fg_grouped_gemm_float_scale_ragged,
                       fg_grouped_gemm_integer_scale_ragged,
                       grouped_w4a16_gemm_ragged)
from .w4a8_gemm import fg_gemm_integer_scale
from .w4a8_gemm_fscale import fg_gemm_float_scale
from .w4a16_gemm import w4a16_gemm


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Kernel launch configuration: BlockSpec tile sizes + interpret mode.

    Divisibility is validated at construction (not at the first traced
    call): ``bm`` must be a multiple of 8 (the f32 sublane tile — the
    kernels snap it down to ``round_up(C, 8)`` for small decode batches),
    ``bn``/``bk`` multiples of 128 (the lane tile; ``bk`` must also hold
    whole quantization groups, which the kernels enforce against the
    qspec's ``group_size`` since that is a property of the weights, not of
    the launch). The defaults mirror every GEMM kernel's own defaults.
    """

    bm: int = 128
    bn: int = 256
    bk: int = 512
    interpret: bool = False

    def __post_init__(self):
        for name, val, mult in (("bm", self.bm, 8), ("bn", self.bn, 128),
                                ("bk", self.bk, 128)):
            if not isinstance(val, int) or val <= 0 or val % mult:
                raise ValueError(
                    f"BlockConfig.{name}={val!r}: must be a positive "
                    f"multiple of {mult} (BlockSpec tile divisibility)")

    def kernel_kwargs(self) -> dict:
        """Splat into the underlying Pallas wrapper call."""
        return {"bm": self.bm, "bn": self.bn, "bk": self.bk,
                "interpret": self.interpret}


#: Default launch config for CPU-validated kernels (tests/benchmarks).
INTERPRET = BlockConfig(interpret=True)


def _as_block(block, interpret=None) -> BlockConfig:
    """Coerce None | legacy dict | BlockConfig (+ interpret override)."""
    if block is None:
        blk = BlockConfig()
    elif isinstance(block, BlockConfig):
        blk = block
    elif isinstance(block, dict):
        warnings.warn(
            "block=dict(...) is deprecated; pass kernels.ops.BlockConfig",
            DeprecationWarning, stacklevel=3)
        blk = BlockConfig(**block)
    else:
        raise TypeError(f"block must be BlockConfig or None, got "
                        f"{type(block).__name__}")
    if interpret is not None and interpret != blk.interpret:
        blk = dataclasses.replace(blk, interpret=bool(interpret))
    return blk


def _resolve_alpha(alpha, qspec: QuantSpec):
    """Amplifier for the integer-scale epilogue.

    The stored per-layer/per-expert ``params["alpha"]`` always wins — it is
    the value the PR-3 overflow certificate covers (possibly capped below
    the qspec's request). Without it, a static integer ``qspec.amplifier``
    is an exact fallback; heuristic amplifiers resolve per layer at
    quantization time, so silently substituting a constant would rescale
    the output by an arbitrary factor AND bypass certification — raise.
    """
    if alpha is not None:
        return alpha
    if isinstance(qspec.amplifier, int):
        return float(qspec.amplifier)
    raise ValueError(
        f"qspec.amplifier={qspec.amplifier!r} is resolved per layer at "
        "quantization time; pass the stored per-layer alpha "
        "(params['alpha']) — no static fallback exists for heuristic "
        "amplifiers")


def _legacy_params(qvalue, scale, alpha) -> dict:
    params = {"qvalue": qvalue, "scale": scale}
    if alpha is not None:
        params["alpha"] = alpha
    return params


def qgemm(
    x: jax.Array,         # (M, K) bf16/f32 activations
    params: dict,         # qlinear param dict: qvalue, scale, alpha?
    qspec: QuantSpec = None,
    *legacy,
    alpha=None,
    interpret: bool | None = None,
    block: BlockConfig | dict | None = None,
) -> jax.Array:
    """Quantized GEMM honoring ``qspec``; returns f32 (M, N).

    Scheme dispatch (weight-only W4A16 / fine-grained integer scale /
    float scale) comes from the qspec; operands from the param dict.
    """
    if legacy:  # v1 positional form: qgemm(x, qvalue, scale, qspec, ...)
        warnings.warn(
            "qgemm(x, qvalue, scale, qspec) is deprecated; pass the param "
            "dict: qgemm(x, {'qvalue': .., 'scale': .., 'alpha': ..}, "
            "qspec)", DeprecationWarning, stacklevel=2)
        if len(legacy) != 1:
            raise TypeError(f"qgemm takes (x, params, qspec); got "
                            f"{3 + len(legacy)} positional args")
        params, qspec = _legacy_params(params, qspec, alpha), legacy[0]
    elif not isinstance(params, dict):
        raise TypeError(
            "qgemm now takes the qlinear param dict as its second "
            "argument (see the migration table in kernels/ops.py)")
    blk = _as_block(block, interpret)
    kw = blk.kernel_kwargs()

    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return w4a16_gemm(x, params["qvalue"], params["scale"],
                          group_size=qspec.group_size, **kw)

    xq, sa = act_quant(x, bits=qspec.a_bits, interpret=blk.interpret)
    if qspec.scale_mode == "integer" and qspec.fine_grained:
        a = _resolve_alpha(params.get("alpha"), qspec)
        if not isinstance(a, (int, float)):
            # traced per-layer amplifier: fold 1/alpha into sa (exact for
            # the power-of-two alphas the heuristic emits)
            sa = sa / jnp.asarray(a, jnp.float32)
            a = 1.0
        return fg_gemm_integer_scale(
            xq, sa, params["qvalue"], params["scale"],
            group_size=qspec.group_size, alpha=float(a),
            w_bits=qspec.w_bits, **kw)
    return fg_gemm_float_scale(
        xq, sa, params["qvalue"], params["scale"],
        group_size=qspec.group_size, w_bits=qspec.w_bits, **kw)


def qgemm_grouped(
    x: jax.Array,         # (E, C, K) bf16/f32 dispatch buffer
    params: dict,         # stacked per-expert param dict
    qspec: QuantSpec = None,
    *legacy,
    alpha=None,
    row_counts=None,      # int32 (E,) routed rows per expert | None=all C
    interpret: bool | None = None,
    block: BlockConfig | dict | None = None,
) -> jax.Array:
    """Batched-expert quantized GEMM; returns f32 (E, C, N).

    Always routes through the ragged scalar-prefetch kernels
    (``kernels.moe_gemm``): activation quantization happens INSIDE the
    grouped kernel's first k-group pass (no dense ``act_quant`` sweep over
    the ``(E*C, K)`` buffer), and when ``row_counts`` is given, m-tiles
    entirely past an expert's routed row count are skipped. ``row_counts``
    is a data operand (traced under jit — the serving engine feeds the
    live per-tick dispatch counts without retracing). Rows at or past
    ``row_counts[e]`` must be zero-filled (the MoE dispatch guarantees
    this); ``row_counts=None`` treats every capacity slot as routed.
    """
    if legacy:  # v1 positional form
        warnings.warn(
            "qgemm_grouped(x, qvalue, scale, qspec) is deprecated; pass "
            "the stacked param dict instead", DeprecationWarning,
            stacklevel=2)
        if len(legacy) != 1:
            raise TypeError(f"qgemm_grouped takes (x, params, qspec); got "
                            f"{3 + len(legacy)} positional args")
        params, qspec = _legacy_params(params, qspec, alpha), legacy[0]
    elif not isinstance(params, dict):
        raise TypeError(
            "qgemm_grouped now takes the stacked qlinear param dict as "
            "its second argument (see the migration table in "
            "kernels/ops.py)")
    blk = _as_block(block, interpret)
    kw = blk.kernel_kwargs()

    if qspec.weight_only:
        if qspec.w_bits != 4:
            raise NotImplementedError("weight-only kernel is W4A16")
        return grouped_w4a16_gemm_ragged(
            x, row_counts, params["qvalue"], params["scale"],
            group_size=qspec.group_size, **kw)

    if qspec.scale_mode == "integer" and qspec.fine_grained:
        a = _resolve_alpha(params.get("alpha"), qspec)
        return fg_grouped_gemm_integer_scale_ragged(
            x, row_counts, params["qvalue"], params["scale"],
            group_size=qspec.group_size, alpha=a,
            a_bits=qspec.a_bits, w_bits=qspec.w_bits, **kw)
    return fg_grouped_gemm_float_scale_ragged(
        x, row_counts, params["qvalue"], params["scale"],
        group_size=qspec.group_size, a_bits=qspec.a_bits,
        w_bits=qspec.w_bits, **kw)


# ---------------------------------------------------------------------------
# v1 deprecation shims (one release; see module docstring migration table)
# ---------------------------------------------------------------------------


def qgemm_from_params(x, params: dict, qspec: QuantSpec, *, interpret=False,
                      block=None):
    """Deprecated alias of :func:`qgemm` (the param-dict form is now the
    primary signature)."""
    warnings.warn("qgemm_from_params is deprecated; call qgemm(x, params, "
                  "qspec, block=...) directly", DeprecationWarning,
                  stacklevel=2)
    return qgemm(x, params, qspec, interpret=interpret, block=block)


def qgemm_grouped_from_params(x, params: dict, qspec: QuantSpec, *,
                              row_counts=None, interpret=False, block=None):
    """Deprecated alias of :func:`qgemm_grouped`."""
    warnings.warn("qgemm_grouped_from_params is deprecated; call "
                  "qgemm_grouped(x, params, qspec, row_counts=..., "
                  "block=...) directly", DeprecationWarning, stacklevel=2)
    return qgemm_grouped(x, params, qspec, row_counts=row_counts,
                         interpret=interpret, block=block)
