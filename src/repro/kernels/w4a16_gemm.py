"""Marlin-analog Pallas kernel: fine-grained W4A16 weight-only GEMM.

The paper benchmarks against Marlin's W4A16 (Fig. 1/5, Table 6). Marlin's
CUDA tricks (async copy, ldmatrix interleave, stream-K) don't transfer;
the TPU-idiomatic equivalent is: nibble-packed int4 weights streamed
HBM->VMEM (4x less weight bandwidth than bf16 — the entire point of
weight-only quant in the memory-bound decode regime), dequantized in-VMEM
to bf16 with the per-group float scale, then bf16 MXU matmul with f32
accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .w4a8_gemm import _round_up, _snap_block, _unpack_wblock


def _dequant_group_accumulate(x, wp, s, facc, *, gs: int,
                              groups_per_blk: int):
    """Shared weight-only block body (also used by the grouped MoE kernel):
    unpack int4, dequant each group to bf16 with its float scale, bf16 MXU
    matmul with f32 accumulation."""
    wfull = _unpack_wblock(wp, gs * groups_per_blk)
    for gi in range(groups_per_blk):
        xg = x[:, gi * gs:(gi + 1) * gs]  # (bm, gs) bf16
        wg = wfull[gi * gs:(gi + 1) * gs, :]  # (gs, bn) int8
        wd = (wg.astype(jnp.float32) * s[gi, :][None, :]).astype(
            jnp.bfloat16
        )
        facc = facc + jax.lax.dot_general(
            xg, wd, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return facc


def _kernel(x_ref, wp_ref, s_ref, o_ref, facc_ref, *,
            nk: int, gs: int, groups_per_blk: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        facc_ref[...] = jnp.zeros_like(facc_ref)

    facc_ref[...] = _dequant_group_accumulate(
        x_ref[...], wp_ref[...], s_ref[...], facc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = facc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bn", "bk", "interpret", "out_dtype"),
)
def w4a16_gemm(
    x: jax.Array,      # bf16 (M, K)
    qvalue: jax.Array, # int8 (K/2, N) packed
    scale: jax.Array,  # f32 (K/g, N)
    *,
    group_size: int = 128,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    M, K = x.shape
    N = qvalue.shape[1]
    gs = group_size
    bm = min(bm, _round_up(M, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs
    nk = K // bk
    groups_per_blk = bk // gs

    Mp = _round_up(M, bm)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, gs=gs,
                          groups_per_blk=groups_per_blk, out_dtype=out_dtype),
        grid=(Mp // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((groups_per_blk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), qvalue, scale)
    return out[:M]
