"""Fused per-token activation quantization kernel (producer for the qGEMMs).

Per-token symmetric absmax int8 quantization of the last axis — the
activation-side half of W4A8/W8A8 (paper §5.1 "per-token activation
quantization"). Fusing this into a single VMEM pass (read bf16 row, write
int8 row + f32 scale) is part of the FastGEMM-style fusion the paper
borrows from OdysseyLLM (§4.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .w4a8_gemm import _round_up


def _quantize_rows(x: jax.Array, *, qm: float):
    """Shared block body: per-row symmetric absmax int8 quantization.

    Used by the standalone ``act_quant`` kernel below AND by the ragged
    grouped MoE GEMM (``moe_gemm``), which folds this into its first
    k-group pass — both paths MUST run the exact same f32 ops so fused and
    unfused activation quantization stay bit-identical. The ``1e-8`` amax
    floor keeps all-zero (capacity-padded) rows finite; their codes are
    still exactly zero.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qm
    q = jnp.clip(jnp.round(xf / scale), -qm, qm).astype(jnp.int8)
    return q, scale


def _kernel(x_ref, q_ref, s_ref, *, qm: float):
    q, scale = _quantize_rows(x_ref[...], qm=qm)
    q_ref[...] = q
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def act_quant(
    x: jax.Array,  # (M, K) bf16/f32
    *,
    bits: int = 8,
    bm: int = 256,
    interpret: bool = False,
):
    """Returns (q int8 (M,K), scale f32 (M,1))."""
    M, K = x.shape
    qm = float(2 ** (bits - 1) - 1)
    bm = min(bm, _round_up(M, 8))
    Mp = _round_up(M, bm)
    if Mp != M:
        # pad with ones (not zeros) so padded rows have a sane nonzero amax
        x = jnp.pad(x, ((0, Mp - M), (0, 0)), constant_values=1)
    q, s = pl.pallas_call(
        functools.partial(_kernel, qm=qm),
        grid=(Mp // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, K), jnp.int8),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:M], s[:M]
