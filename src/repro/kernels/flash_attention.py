"""Pallas TPU flash attention (fwd) — the prefill hot-spot kernel.

The roofline shows 32k prefill on the big dense archs is compute-bound
(rf 0.73-0.82) with attention the second-largest FLOPs term after the
quantized GEMMs; a fused flash kernel removes the HBM round-trips of the
pure-JAX chunked scan (models/attention.py) between score/softmax/AV
stages.

Design (one (batch x kv-head) program per grid row):
  grid = (B*Hkv*G, Sq/bq, Sk/bk); online-softmax state (m, l) and the
  f32 accumulator live in VMEM scratch across the KV grid dimension;
  causal masking by absolute positions; the KV-block loop is the minor
  grid dim so the accumulator revisits stay in VMEM. Blocks default
  bq=256, bk=512: q tile 256x128 bf16 = 64 KiB, k/v tiles 512x128 = 128
  KiB each, acc 256x128 f32 = 128 KiB — far under VMEM, pipeline can
  double-buffer.

Validated vs ref.py / models.attention.flash_attention in interpret mode
(tests/test_flash_kernel.py). Used on real TPUs via kernels.ops; the
dry-run keeps the pure-JAX path (interpret lowering on 512 host devices
would be pointless work for identical HLO semantics).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .w4a8_gemm import _round_up

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, bq: int, bk: int, sq: int, sk: int, causal: bool,
            window: int | None, scale: float):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
    k = k_ref[0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0].astype(jnp.float32)                # (bk, dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < sk
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softmax_scale", "bq", "bk",
                     "interpret"),
)
def flash_attention_tpu(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    softmax_scale: float | None = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape[0], k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    scale = softmax_scale or (1.0 / math.sqrt(D))
    bq = min(bq, _round_up(Sq, 8))
    bk = min(bk, _round_up(Sk, 128))
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)

    # layout: fold (B, Hkv, G) into one leading "row" dim; each grid row
    # attends one query-head against its kv head.
    qr = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3).reshape(B * Hq, Sqp, D)
    kr = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    kr = kr.transpose(0, 2, 1, 3).reshape(B * Hkv, Skp, D)
    vr = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    vr = vr.transpose(0, 2, 1, 3).reshape(B * Hkv, Skp, Dv)

    nq, nk = Sqp // bq, Skp // bk
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, sq=Sq, sk=Sk,
                          causal=causal, window=window, scale=scale),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda r, i, j: (r, i, 0)),
            pl.BlockSpec((1, bk, D), lambda r, i, j: (r // G, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda r, i, j: (r // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda r, i, j: (r, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, Dv), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, Hq, Sqp, Dv).transpose(0, 2, 1, 3)
    return out[:, :Sq]
