"""Pure-jnp oracles mirroring each Pallas kernel's exact I/O contract.

Every oracle takes the *same packed/quantized operands* as its kernel so
tests compare kernel-vs-oracle bit-exactly on the integer path (and to f32
ulp tolerance on the float epilogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import unpack_int4


def act_quant_ref(x: jax.Array, bits: int = 8):
    """Per-token symmetric absmax quantization of the last axis."""
    qm = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qm
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qm, qm)
    return q.astype(jnp.int8), scale


def _unpack_w(qvalue: jax.Array, w_bits: int, group_size: int, K: int):
    if w_bits == 4:
        return unpack_int4(qvalue)
    return qvalue


def fg_gemm_is_ref(
    xq: jax.Array,      # int8 (M, K)
    sa: jax.Array,      # f32 (M, 1)
    qvalue: jax.Array,  # int8 (K/2, N) packed (w4) or (K, N) (w8)
    int_scale: jax.Array,  # int32 (K/g, N)
    *,
    group_size: int,
    alpha: float,
    w_bits: int = 4,
) -> jax.Array:
    """Eq. 2 oracle: int32 group accumulation, single final convert."""
    M, K = xq.shape
    w = _unpack_w(qvalue, w_bits, group_size, K)
    N = w.shape[1]
    G = K // group_size
    x3 = xq.reshape(M, G, group_size)
    w3 = w.reshape(G, group_size, N)
    part = jax.lax.dot_general(
        x3, w3, (((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (G, M, N)
    acc = jnp.sum(part * int_scale[:, None, :], axis=0)  # int32
    return acc.astype(jnp.float32) * (sa / alpha)


def fg_gemm_fs_ref(
    xq: jax.Array,
    sa: jax.Array,
    qvalue: jax.Array,
    scale: jax.Array,  # f32 (K/g, N) fine  or (1, N) coarse
    *,
    group_size: int,  # -1 => coarse
    w_bits: int = 4,
) -> jax.Array:
    """Eq. 1 oracle: per-group I32->F32 convert + float-scale accumulate."""
    M, K = xq.shape
    gs = group_size if group_size > 0 else K
    w = _unpack_w(qvalue, w_bits, group_size, K)
    N = w.shape[1]
    G = K // gs
    x3 = xq.reshape(M, G, gs)
    w3 = w.reshape(G, gs, N)
    part = jax.lax.dot_general(
        x3, w3, (((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32,
    )  # (G, M, N)
    acc = jnp.sum(part.astype(jnp.float32) * scale[:, None, :], axis=0)
    return acc * sa


def w4a16_gemm_ref(
    x: jax.Array,       # bf16/f32 (M, K)
    qvalue: jax.Array,  # int8 (K/2, N) packed
    scale: jax.Array,   # f32 (K/g, N)
    *,
    group_size: int,
) -> jax.Array:
    """Marlin-analog oracle: in-register dequant then fp GEMM, f32 accum."""
    M, K = x.shape
    w = unpack_int4(qvalue)
    N = w.shape[1]
    G = K // group_size
    wd = (w.reshape(G, group_size, N).astype(jnp.float32)
          * scale[:, None, :]).reshape(K, N)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), wd.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
