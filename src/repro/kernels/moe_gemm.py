"""Grouped (batched-expert) Pallas TPU kernels for MoE FFNs (paper §5.5).

The Mixtral headline result needs every expert's fine-grained W4A8 GEMM to
run through the integer-scale fast path. ``jax.vmap`` over the reference
GEMM materializes E independent XLA dots with per-group float bookkeeping;
instead these kernels run ONE ``pallas_call`` whose grid iterates
``(experts, m-tiles, n-tiles, k-groups)`` over the dense dispatch buffer —
the Marlin/FPTQ-style batched-expert GEMM, with the expert index just an
extra (outermost) grid dimension selecting the weight/scale slabs.

All three quantization schemes ride the same structure:

  * ``fg_grouped_gemm_integer_scale`` — Eq. 2 per expert: int32 group
    accumulation, ONE convert per output tile. Per-expert amplifiers
    (heuristic recipes give each expert its own alpha) are folded into the
    per-token activation scale ``sa`` before the kernel, so the epilogue is
    identical to the single-expert kernel.
  * ``fg_grouped_gemm_float_scale`` — Eq. 1 baseline (per-group converts),
    also serves coarse per-channel scales (``group_size=-1``).
  * ``grouped_w4a16_gemm`` — weight-only Marlin-analog (in-VMEM dequant to
    bf16, fp MXU matmul).

The block bodies are the SAME helpers the dense kernels use
(``w4a8_gemm._group_accumulate`` / ``w4a16_gemm._dequant_group_accumulate``)
— the grouped kernels add only the expert grid dimension and blocked
indexing, so dense-vs-grouped can never drift numerically.

Capacity slots beyond the routed token count arrive zero-filled from the
MoE dispatch; int8 zero rows contribute zero partials, so padded slots cost
MXU work but stay exact. ``ops.qgemm_grouped`` does quantize those zero
rows (``act_quant``'s ``maximum(amax, 1e-8)`` floor keeps their scales
finite — do not remove that guard while capacity padding exists); their
quantized codes are still all-zero, so outputs for padded slots are
exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .w4a8_gemm import (_group_accumulate, _round_up, _snap_block)
from .w4a16_gemm import _dequant_group_accumulate


def _grouped_kernel(x_ref, wp_ref, s_ref, sa_ref, o_ref, acc_ref, *,
                    nk: int, gs: int, groups_per_blk: int, w_bits: int,
                    integer: bool, coarse: bool, out_dtype):
    """One (expert, m, n) output tile; k innermost accumulates groups."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = _group_accumulate(
        x_ref[0], wp_ref[0], s_ref[0], acc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk, w_bits=w_bits,
        integer=integer, coarse=coarse)

    @pl.when(k == nk - 1)
    def _epilogue():
        if integer:
            # ONE I32->F32 convert per output tile; 1/alpha pre-folded
            # into sa by the wrapper (per-expert alphas supported).
            o_ref[0] = (acc_ref[...].astype(jnp.float32)
                        * sa_ref[0]).astype(out_dtype)
        else:
            o_ref[0] = (acc_ref[...] * sa_ref[0]).astype(out_dtype)


def _grouped_blocks(E, Cp, K, N, bm, bn, bk, *, pack, s_rows, coarse):
    """Grid + BlockSpecs shared by the int- and float-scale variants."""
    nk = K // bk
    grid = (E, Cp // bm, N // bn, nk)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
        pl.BlockSpec((1, bk // pack, bn), lambda e, i, j, k: (e, k, j)),
        pl.BlockSpec((1, s_rows, bn),
                     (lambda e, i, j, k: (e, 0, j)) if coarse
                     else (lambda e, i, j, k: (e, k, j))),
        pl.BlockSpec((1, bm, 1), lambda e, i, j, k: (e, i, 0)),
    ]
    out_spec = pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j))
    return grid, in_specs, out_spec, nk


def _pad_tokens(x, sa, C, bm):
    Cp = _round_up(C, bm)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))
        sa = jnp.pad(sa, ((0, 0), (0, Cp - C), (0, 0)))
    return x, sa, Cp


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "w_bits", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def fg_grouped_gemm_integer_scale(
    xq: jax.Array,        # int8 (E, C, K) dispatch buffer
    sa: jax.Array,        # f32 (E, C, 1) per-token scales
    qvalue: jax.Array,    # int8 (E, K/2, N) packed (w4) | (E, K, N) (w8)
    int_scale: jax.Array, # int32 (E, K/g, N)
    *,
    group_size: int = 128,
    alpha=1024.0,         # python float, or f32 (E,) per-expert amplifiers
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched-expert Eq. 2 GEMM: (E,C,K) x (E,K,N) -> (E,C,N) f32."""
    E, C, K = xq.shape
    N = qvalue.shape[2]
    gs = group_size
    if K % gs:
        raise ValueError(f"K={K} % group={gs}")
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs  # block must hold whole groups
    groups_per_blk = bk // gs

    # Fold per-expert 1/alpha into the activation scales (exact for the
    # power-of-two amplifiers Listing 1 produces).
    a = jnp.asarray(alpha, jnp.float32)
    sa = sa / (a.reshape(E, 1, 1) if a.ndim == 1 else a)

    xq, sa, Cp = _pad_tokens(xq, sa, C, bm)
    pack = 2 if w_bits == 4 else 1
    grid, in_specs, out_spec, nk = _grouped_blocks(
        E, Cp, K, N, bm, bn, bk, pack=pack, s_rows=groups_per_blk,
        coarse=False)
    out = pl.pallas_call(
        functools.partial(
            _grouped_kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, integer=True, coarse=False, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, qvalue, int_scale, sa)
    return out[:, :C]


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "w_bits", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def fg_grouped_gemm_float_scale(
    xq: jax.Array,     # int8 (E, C, K)
    sa: jax.Array,     # f32 (E, C, 1)
    qvalue: jax.Array, # int8 (E, K/2, N) packed (w4) | (E, K, N) (w8)
    scale: jax.Array,  # f32 (E, K/g, N) fine | (E, 1, N) coarse
    *,
    group_size: int = 128,  # -1 => coarse
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched-expert Eq. 1 baseline (per-group converts in the loop)."""
    E, C, K = xq.shape
    N = qvalue.shape[2]
    coarse = group_size <= 0
    gs = K if coarse else group_size
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), 1 if coarse else gs)
    if not coarse and bk % gs:
        bk = gs
    if coarse:
        gs = bk  # each K-block is one "group" with the constant scale
    groups_per_blk = bk // gs

    xq, sa, Cp = _pad_tokens(xq, sa, C, bm)
    pack = 2 if w_bits == 4 else 1
    grid, in_specs, out_spec, nk = _grouped_blocks(
        E, Cp, K, N, bm, bn, bk, pack=pack,
        s_rows=1 if coarse else groups_per_blk, coarse=coarse)
    out = pl.pallas_call(
        functools.partial(
            _grouped_kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, integer=False, coarse=coarse, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xq, qvalue, scale, sa)
    return out[:, :C]


def _grouped_wo_kernel(x_ref, wp_ref, s_ref, o_ref, facc_ref, *,
                       nk: int, gs: int, groups_per_blk: int, out_dtype):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        facc_ref[...] = jnp.zeros_like(facc_ref)

    facc_ref[...] = _dequant_group_accumulate(
        x_ref[0], wp_ref[0], s_ref[0], facc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[0] = facc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def grouped_w4a16_gemm(
    x: jax.Array,      # bf16 (E, C, K)
    qvalue: jax.Array, # int8 (E, K/2, N) packed
    scale: jax.Array,  # f32 (E, K/g, N)
    *,
    group_size: int = 128,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched-expert weight-only Marlin-analog: (E,C,K) -> (E,C,N)."""
    E, C, K = x.shape
    N = qvalue.shape[2]
    gs = group_size
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs
    groups_per_blk = bk // gs

    Cp = _round_up(C, bm)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))
    grid, in_specs, out_spec, nk = _grouped_blocks(
        E, Cp, K, N, bm, bn, bk, pack=2, s_rows=groups_per_blk,
        coarse=False)
    out = pl.pallas_call(
        functools.partial(_grouped_wo_kernel, nk=nk, gs=gs,
                          groups_per_blk=groups_per_blk,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=in_specs[:3],  # no sa operand on the weight-only path
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), qvalue, scale)
    return out[:, :C]
