"""Grouped (batched-expert) Pallas TPU kernels for MoE FFNs (paper §5.5).

The Mixtral headline result needs every expert's fine-grained W4A8 GEMM to
run through the integer-scale fast path. ``jax.vmap`` over the reference
GEMM materializes E independent XLA dots with per-group float bookkeeping;
instead these kernels run ONE ``pallas_call`` whose grid iterates
``(experts, m-tiles, n-tiles, k-groups)`` over the dense dispatch buffer —
the Marlin/FPTQ-style batched-expert GEMM, with the expert index just an
extra (outermost) grid dimension selecting the weight/scale slabs.

All three quantization schemes ride the same structure:

  * ``fg_grouped_gemm_integer_scale`` — Eq. 2 per expert: int32 group
    accumulation, ONE convert per output tile. Per-expert amplifiers
    (heuristic recipes give each expert its own alpha) are folded into the
    per-token activation scale ``sa`` before the kernel, so the epilogue is
    identical to the single-expert kernel.
  * ``fg_grouped_gemm_float_scale`` — Eq. 1 baseline (per-group converts),
    also serves coarse per-channel scales (``group_size=-1``).
  * ``grouped_w4a16_gemm`` — weight-only Marlin-analog (in-VMEM dequant to
    bf16, fp MXU matmul).

The block bodies are the SAME helpers the dense kernels use
(``w4a8_gemm._group_accumulate`` / ``w4a16_gemm._dequant_group_accumulate``)
— the grouped kernels add only the expert grid dimension and blocked
indexing, so dense-vs-grouped can never drift numerically.

Capacity slots beyond the routed token count arrive zero-filled from the
MoE dispatch; int8 zero rows contribute zero partials, so padded slots cost
MXU work but stay exact.

Ragged scalar-prefetch variants (the ``*_ragged`` entry points)
---------------------------------------------------------------

The dense kernels above burn a full m-tile of MACs per capacity-padded
tile. The ragged variants take the per-expert routed row counts as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``) and skip every
m-tile that starts at or past its expert's count. The contract:

  * ``row_counts`` is int32 ``(E,)``; rows ``[0, row_counts[e])`` of expert
    ``e``'s capacity slab are routed tokens, every row at or past
    ``row_counts[e]`` MUST be zero-filled (exactly what the sort-based
    dispatch in ``models.moe`` produces). Counts are clamped to ``C``.
  * the grid still statically covers ``(E, C/bm, N/bn, K/bk)``, but for an
    inactive m-tile the block index maps clamp every operand to an
    already-resident block (no DMA is issued for a revisited block) and
    ``pl.when`` skips the quant/MXU body, so inactive grid steps cost only
    grid bookkeeping; the epilogue writes exact zeros for them. Executed
    m-tile work drops from ``E * ceil(C/bm)`` to
    ``sum_e ceil(row_counts[e]/bm)`` (see :func:`ragged_tile_stats`).
  * activation quantization is FUSED: the ragged W4A8 kernels consume the
    raw bf16/f32 dispatch buffer and quantize each (bm, K) row-block once
    into VMEM scratch on the tile's first (j==0, k==0) pass, reusing the
    codes for every n-tile/k-group — ``ops.qgemm_grouped`` no longer runs
    the dense ``act_quant`` kernel over the full ``(E*C, K)`` buffer, so
    the padded slots are never even quantized. The in-kernel math is
    ``act_quant._quantize_rows`` verbatim, which keeps fused and unfused
    paths bit-identical.
  * bit-exactness invariant: for any zero-filled-past-count input, ragged
    output == dense grouped output, element for element, including
    per-expert alphas (the epilogue divides by alpha with the same op
    order the dense wrapper uses when folding 1/alpha into ``sa``).

With ragged skipping in place the ``act_quant`` ``maximum(amax, 1e-8)``
floor is no longer what keeps padded slots sane on the grouped path (they
are skipped outright, and partial-tile zero rows quantize to zero codes
regardless of the floor); the floor still protects genuinely all-zero
*routed* rows and the dense/standalone users, so it stays — but it can now
be revisited independently of MoE capacity padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .act_quant import _quantize_rows
from .w4a8_gemm import (_cdiv, _group_accumulate, _round_up, _snap_block)
from .w4a16_gemm import _dequant_group_accumulate


def _grouped_kernel(x_ref, wp_ref, s_ref, sa_ref, o_ref, acc_ref, *,
                    nk: int, gs: int, groups_per_blk: int, w_bits: int,
                    integer: bool, coarse: bool, out_dtype):
    """One (expert, m, n) output tile; k innermost accumulates groups."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = _group_accumulate(
        x_ref[0], wp_ref[0], s_ref[0], acc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk, w_bits=w_bits,
        integer=integer, coarse=coarse)

    @pl.when(k == nk - 1)
    def _epilogue():
        if integer:
            # ONE I32->F32 convert per output tile; 1/alpha pre-folded
            # into sa by the wrapper (per-expert alphas supported).
            o_ref[0] = (acc_ref[...].astype(jnp.float32)
                        * sa_ref[0]).astype(out_dtype)
        else:
            o_ref[0] = (acc_ref[...] * sa_ref[0]).astype(out_dtype)


def _grouped_blocks(E, Cp, K, N, bm, bn, bk, *, pack, s_rows, coarse):
    """Grid + BlockSpecs shared by the int- and float-scale variants."""
    nk = K // bk
    grid = (E, Cp // bm, N // bn, nk)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
        pl.BlockSpec((1, bk // pack, bn), lambda e, i, j, k: (e, k, j)),
        pl.BlockSpec((1, s_rows, bn),
                     (lambda e, i, j, k: (e, 0, j)) if coarse
                     else (lambda e, i, j, k: (e, k, j))),
        pl.BlockSpec((1, bm, 1), lambda e, i, j, k: (e, i, 0)),
    ]
    out_spec = pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j))
    return grid, in_specs, out_spec, nk


def _pad_tokens(x, sa, C, bm):
    Cp = _round_up(C, bm)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))
        sa = jnp.pad(sa, ((0, 0), (0, Cp - C), (0, 0)))
    return x, sa, Cp


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "w_bits", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def fg_grouped_gemm_integer_scale(
    xq: jax.Array,        # int8 (E, C, K) dispatch buffer
    sa: jax.Array,        # f32 (E, C, 1) per-token scales
    qvalue: jax.Array,    # int8 (E, K/2, N) packed (w4) | (E, K, N) (w8)
    int_scale: jax.Array, # int32 (E, K/g, N)
    *,
    group_size: int = 128,
    alpha=1024.0,         # python float, or f32 (E,) per-expert amplifiers
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched-expert Eq. 2 GEMM: (E,C,K) x (E,K,N) -> (E,C,N) f32."""
    E, C, K = xq.shape
    N = qvalue.shape[2]
    gs = group_size
    if K % gs:
        raise ValueError(f"K={K} % group={gs}")
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs  # block must hold whole groups
    groups_per_blk = bk // gs

    # Fold per-expert 1/alpha into the activation scales (exact for the
    # power-of-two amplifiers Listing 1 produces).
    a = jnp.asarray(alpha, jnp.float32)
    sa = sa / (a.reshape(E, 1, 1) if a.ndim == 1 else a)

    xq, sa, Cp = _pad_tokens(xq, sa, C, bm)
    pack = 2 if w_bits == 4 else 1
    grid, in_specs, out_spec, nk = _grouped_blocks(
        E, Cp, K, N, bm, bn, bk, pack=pack, s_rows=groups_per_blk,
        coarse=False)
    out = pl.pallas_call(
        functools.partial(
            _grouped_kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, integer=True, coarse=False, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, qvalue, int_scale, sa)
    return out[:, :C]


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "w_bits", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def fg_grouped_gemm_float_scale(
    xq: jax.Array,     # int8 (E, C, K)
    sa: jax.Array,     # f32 (E, C, 1)
    qvalue: jax.Array, # int8 (E, K/2, N) packed (w4) | (E, K, N) (w8)
    scale: jax.Array,  # f32 (E, K/g, N) fine | (E, 1, N) coarse
    *,
    group_size: int = 128,  # -1 => coarse
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched-expert Eq. 1 baseline (per-group converts in the loop)."""
    E, C, K = xq.shape
    N = qvalue.shape[2]
    coarse = group_size <= 0
    gs = K if coarse else group_size
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), 1 if coarse else gs)
    if not coarse and bk % gs:
        bk = gs
    if coarse:
        gs = bk  # each K-block is one "group" with the constant scale
    groups_per_blk = bk // gs

    xq, sa, Cp = _pad_tokens(xq, sa, C, bm)
    pack = 2 if w_bits == 4 else 1
    grid, in_specs, out_spec, nk = _grouped_blocks(
        E, Cp, K, N, bm, bn, bk, pack=pack,
        s_rows=1 if coarse else groups_per_blk, coarse=coarse)
    out = pl.pallas_call(
        functools.partial(
            _grouped_kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, integer=False, coarse=coarse, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xq, qvalue, scale, sa)
    return out[:, :C]


def _grouped_wo_kernel(x_ref, wp_ref, s_ref, o_ref, facc_ref, *,
                       nk: int, gs: int, groups_per_blk: int, out_dtype):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        facc_ref[...] = jnp.zeros_like(facc_ref)

    facc_ref[...] = _dequant_group_accumulate(
        x_ref[0], wp_ref[0], s_ref[0], facc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[0] = facc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def grouped_w4a16_gemm(
    x: jax.Array,      # bf16 (E, C, K)
    qvalue: jax.Array, # int8 (E, K/2, N) packed
    scale: jax.Array,  # f32 (E, K/g, N)
    *,
    group_size: int = 128,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched-expert weight-only Marlin-analog: (E,C,K) -> (E,C,N)."""
    E, C, K = x.shape
    N = qvalue.shape[2]
    gs = group_size
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs
    groups_per_blk = bk // gs

    Cp = _round_up(C, bm)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))
    grid, in_specs, out_spec, nk = _grouped_blocks(
        E, Cp, K, N, bm, bn, bk, pack=2, s_rows=groups_per_blk,
        coarse=False)
    out = pl.pallas_call(
        functools.partial(_grouped_wo_kernel, nk=nk, gs=gs,
                          groups_per_blk=groups_per_blk,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=in_specs[:3],  # no sa operand on the weight-only path
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), qvalue, scale)
    return out[:, :C]


# ---------------------------------------------------------------------------
# Ragged scalar-prefetch variants (skip m-tiles past each expert's row count)
# ---------------------------------------------------------------------------


def ragged_tile_stats(row_counts, C: int, bm: int = 128) -> dict:
    """Executed-m-tile accounting for a ragged launch (python ints).

    ``dense_m_tiles`` is what the capacity-padded kernel runs; per m-tile
    the full (N/bn, K/bk) inner grid does MXU work, so the ratio is the
    MAC-savings of ragged skipping. Used by benchmarks/CI reporting.
    """
    bm = min(bm, _round_up(C, 8))
    Cp = _round_up(C, bm)
    counts = [min(int(c), C) for c in row_counts]
    dense = len(counts) * (Cp // bm)
    ragged = sum(_cdiv(c, bm) for c in counts)
    return {"bm": bm, "dense_m_tiles": dense, "ragged_m_tiles": ragged}


def _ragged_specs(E, Cp, K, N, bm, bn, bk, *, pack, s_rows, coarse,
                  fused_quant, n_extra=0):
    """Grid + BlockSpecs for the ragged kernels.

    Index maps receive the scalar-prefetch ``row_counts`` ref as a trailing
    arg. Inactive m-tiles clamp every input block index to one that is (or
    was just) resident so the pipeline issues no DMA for them; the output
    map is NOT clamped (inactive tiles must write their zeros).
    """

    def _last_tile(rc, e):
        # index of the last active m-tile (0 when the expert is empty)
        return jnp.maximum(pl.cdiv(rc[e], bm) - 1, 0)

    if fused_quant:
        # raw activations: one full-K row slab per (e, m-tile); quantized
        # into scratch at (j==0, k==0) and reused across every (j, k).
        def x_map(e, i, j, k, rc):
            return (e, jnp.minimum(i, _last_tile(rc, e)), 0)

        x_spec = pl.BlockSpec((1, bm, K), x_map)
    else:
        def x_map(e, i, j, k, rc):
            act = i * bm < rc[e]
            return (e, jnp.minimum(i, _last_tile(rc, e)),
                    jnp.where(act, k, 0))

        x_spec = pl.BlockSpec((1, bm, bk), x_map)

    def w_map(e, i, j, k, rc):
        act = i * bm < rc[e]
        return (e, jnp.where(act, k, 0), jnp.where(act, j, 0))

    def s_map(e, i, j, k, rc):
        act = i * bm < rc[e]
        if coarse:
            return (e, 0, jnp.where(act, j, 0))
        return (e, jnp.where(act, k, 0), jnp.where(act, j, 0))

    nk = K // bk
    grid = (E, Cp // bm, N // bn, nk)
    in_specs = [
        x_spec,
        pl.BlockSpec((1, bk // pack, bn), w_map),
        pl.BlockSpec((1, s_rows, bn), s_map),
    ]
    if n_extra:  # per-expert alpha: (E, 1) f32, one scalar block
        in_specs.append(pl.BlockSpec((1, 1), lambda e, i, j, k, rc: (e, 0)))
    out_spec = pl.BlockSpec((1, bm, bn), lambda e, i, j, k, rc: (e, i, j))
    return grid, in_specs, out_spec, nk


def _ragged_kernel(rc_ref, x_ref, wp_ref, s_ref, a_ref, o_ref,
                   xq_s, sa_s, acc_ref, *,
                   nk: int, gs: int, groups_per_blk: int, w_bits: int,
                   integer: bool, coarse: bool, bm: int, bk: int,
                   qm: float, out_dtype):
    """Ragged W{4,8}A8 tile with FUSED activation quantization.

    Quantizes the (bm, K) row slab once per m-tile (first j/k pass) into
    int8+scale VMEM scratch via the exact ``act_quant`` block body, then
    accumulates k-groups from the scratch codes. Inactive tiles skip all
    of it and write zeros.
    """
    e = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    active = i * bm < rc_ref[e]

    @pl.when(active & (j == 0) & (k == 0))
    def _quant():
        q, s = _quantize_rows(x_ref[0], qm=qm)
        xq_s[...] = q
        sa_s[...] = s

    @pl.when(active)
    def _body():
        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        xblk = xq_s[:, pl.ds(k * bk, bk)]
        acc_ref[...] = _group_accumulate(
            xblk, wp_ref[0], s_ref[0], acc_ref[...],
            gs=gs, groups_per_blk=groups_per_blk, w_bits=w_bits,
            integer=integer, coarse=coarse)

    @pl.when(k == nk - 1)
    def _epilogue():
        @pl.when(active)
        def _write():
            # same op order as the dense wrapper's 1/alpha folding
            # (sa / alpha, then ONE multiply) so ragged == dense bitwise.
            sa = sa_s[...] / a_ref[0]
            if integer:
                o_ref[0] = (acc_ref[...].astype(jnp.float32)
                            * sa).astype(out_dtype)
            else:
                o_ref[0] = (acc_ref[...] * sa).astype(out_dtype)

        @pl.when(jnp.logical_not(active))
        def _zeros():
            o_ref[0] = jnp.zeros_like(o_ref[0])


def _ragged_a8_call(x, row_counts, qvalue, scale, alpha, *, integer: bool,
                    group_size: int, a_bits: int, w_bits: int,
                    bm: int, bn: int, bk: int, interpret: bool, out_dtype):
    """Shared wrapper for the ragged integer-/float-scale W{4,8}A8 kernels."""
    E, C, K = x.shape
    N = qvalue.shape[2]
    coarse = group_size <= 0
    gs = K if coarse else group_size
    if not coarse and K % gs:
        raise ValueError(f"K={K} % group={gs}")
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), 1 if coarse else gs)
    if not coarse and bk % gs:
        bk = gs
    if coarse:
        gs = bk  # each K-block is one "group" with the constant scale
    groups_per_blk = bk // gs
    qm = float(2 ** (a_bits - 1) - 1)

    if row_counts is None:
        rc = jnp.full((E,), C, jnp.int32)
    else:
        rc = jnp.minimum(jnp.asarray(row_counts, jnp.int32), C)

    # per-expert amplifier as an (E, 1) operand (1.0 on the float path)
    a = jnp.broadcast_to(
        jnp.asarray(alpha, jnp.float32).reshape(-1)[:, None], (E, 1))

    Cp = _round_up(C, bm)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))

    pack = 2 if w_bits == 4 else 1
    grid, in_specs, out_spec, nk = _ragged_specs(
        E, Cp, K, N, bm, bn, bk, pack=pack,
        s_rows=1 if coarse else groups_per_blk, coarse=coarse,
        fused_quant=True, n_extra=1)
    acc_dtype = jnp.int32 if integer else jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[
            pltpu.VMEM((bm, K), jnp.int8),     # quantized row slab
            pltpu.VMEM((bm, 1), jnp.float32),  # per-token scales
            pltpu.VMEM((bm, bn), acc_dtype),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _ragged_kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, integer=integer, coarse=coarse, bm=bm, bk=bk,
            qm=qm, out_dtype=out_dtype,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        interpret=interpret,
    )(rc, x, qvalue, scale, a)
    return out[:, :C]


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "a_bits", "w_bits", "bm", "bn", "bk",
                     "interpret", "out_dtype"),
)
def fg_grouped_gemm_integer_scale_ragged(
    x: jax.Array,          # bf16/f32 (E, C, K) RAW dispatch buffer
    row_counts,            # int32 (E,) routed rows per expert, or None
    qvalue: jax.Array,     # int8 (E, K/2, N) packed (w4) | (E, K, N) (w8)
    int_scale: jax.Array,  # int32 (E, K/g, N)
    *,
    group_size: int = 128,
    alpha=1024.0,          # python float, or f32 (E,) per-expert amplifiers
    a_bits: int = 8,
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Ragged batched-expert Eq. 2 GEMM with fused act-quant."""
    return _ragged_a8_call(
        x, row_counts, qvalue, int_scale, alpha, integer=True,
        group_size=group_size, a_bits=a_bits, w_bits=w_bits,
        bm=bm, bn=bn, bk=bk, interpret=interpret, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "a_bits", "w_bits", "bm", "bn", "bk",
                     "interpret", "out_dtype"),
)
def fg_grouped_gemm_float_scale_ragged(
    x: jax.Array,      # bf16/f32 (E, C, K) RAW dispatch buffer
    row_counts,        # int32 (E,) routed rows per expert, or None
    qvalue: jax.Array, # int8 (E, K/2, N) packed (w4) | (E, K, N) (w8)
    scale: jax.Array,  # f32 (E, K/g, N) fine | (E, 1, N) coarse
    *,
    group_size: int = 128,  # -1 => coarse
    a_bits: int = 8,
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Ragged batched-expert Eq. 1 baseline with fused act-quant."""
    return _ragged_a8_call(
        x, row_counts, qvalue, scale, 1.0, integer=False,
        group_size=group_size, a_bits=a_bits, w_bits=w_bits,
        bm=bm, bn=bn, bk=bk, interpret=interpret, out_dtype=out_dtype)


def _ragged_wo_kernel(rc_ref, x_ref, wp_ref, s_ref, o_ref, facc_ref, *,
                      nk: int, gs: int, groups_per_blk: int, bm: int,
                      out_dtype):
    e = pl.program_id(0)
    i = pl.program_id(1)
    k = pl.program_id(3)
    active = i * bm < rc_ref[e]

    @pl.when(active)
    def _body():
        @pl.when(k == 0)
        def _init():
            facc_ref[...] = jnp.zeros_like(facc_ref)

        facc_ref[...] = _dequant_group_accumulate(
            x_ref[0], wp_ref[0], s_ref[0], facc_ref[...],
            gs=gs, groups_per_blk=groups_per_blk)

    @pl.when(k == nk - 1)
    def _epilogue():
        @pl.when(active)
        def _write():
            o_ref[0] = facc_ref[...].astype(out_dtype)

        @pl.when(jnp.logical_not(active))
        def _zeros():
            o_ref[0] = jnp.zeros_like(o_ref[0])


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bn", "bk", "interpret",
                     "out_dtype"),
)
def grouped_w4a16_gemm_ragged(
    x: jax.Array,      # bf16 (E, C, K)
    row_counts,        # int32 (E,) routed rows per expert, or None
    qvalue: jax.Array, # int8 (E, K/2, N) packed
    scale: jax.Array,  # f32 (E, K/g, N)
    *,
    group_size: int = 128,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Ragged batched-expert weight-only Marlin-analog (no act-quant)."""
    E, C, K = x.shape
    N = qvalue.shape[2]
    gs = group_size
    bm = min(bm, _round_up(C, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs
    groups_per_blk = bk // gs

    if row_counts is None:
        rc = jnp.full((E,), C, jnp.int32)
    else:
        rc = jnp.minimum(jnp.asarray(row_counts, jnp.int32), C)

    Cp = _round_up(C, bm)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))
    grid, in_specs, out_spec, nk = _ragged_specs(
        E, Cp, K, N, bm, bn, bk, pack=2, s_rows=groups_per_blk,
        coarse=False, fused_quant=False)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_wo_kernel, nk=nk, gs=gs,
                          groups_per_blk=groups_per_blk, bm=bm,
                          out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, Cp, N), out_dtype),
        interpret=interpret,
    )(rc, x.astype(jnp.bfloat16), qvalue, scale)
    return out[:, :C]
