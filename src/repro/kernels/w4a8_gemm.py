"""Flagship Pallas TPU kernel: fine-grained W4A8 GEMM with Integer Scale.

Implements paper Eq. 2 / Table 2 "Ours":

    C_g = A_g * W_g * s_g^INT + C_{g-1}     (all INT32, MXU + VPU)
    O   = FLOAT(C_G) * s_a / alpha          (ONE convert per output tile)

TPU adaptation (see DESIGN.md §2/§4):
  * per-group int8 x int8 -> int32 matmuls run on the MXU
    (``preferred_element_type=int32``), iterated over the K grid dimension;
  * the per-group *integer* scale multiply + add stays on VPU int32 lanes —
    no I32->F32 convert inside the loop (that is the float-scale
    bottleneck this kernel removes);
  * int4 weights are nibble-packed along K with a group-local (lo, hi)
    layout (``repro.core.packing``) so unpack = 2 shift pairs + one
    sublane-dim concat; no gathers/lane shuffles;
  * int32 accumulator lives in VMEM scratch across the K grid;
  * BlockSpec tiles default to (bm=128, bn=256, bk=512): MXU-aligned
    (multiples of 128 on the contraction/lane dims), VMEM footprint
    ~0.4 MB << 16 MB so the pipeline can double-buffer.

Weight-bit generality: the same kernel body serves W8A8 (``w_bits=8``,
no unpack) — Integer Scale is bit-width agnostic (paper §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


LAYOUT_UNIT = 128  # must match repro.core.packing.LAYOUT_UNIT


def _unpack_wblock(wp: jax.Array, bk: int) -> jax.Array:
    """(bk/2, bn) packed int8 -> (bk, bn) int8, natural k-order.

    The packing layout (repro.core.packing) stores, per 128-row unit, byte b
    = (k=b | k=64+b << 4); unpack per unit is two shift pairs + one
    sublane-dim concat — no permutation. Static unroll over units.
    """
    unit = LAYOUT_UNIT if bk % LAYOUT_UNIT == 0 else bk
    h = unit // 2
    parts = []
    for u in range(bk // unit):
        w32 = wp[u * h:(u + 1) * h, :].astype(jnp.int32)
        lo = (w32 << 28) >> 28
        hi = (w32 << 24) >> 28
        parts.append(jnp.concatenate([lo, hi], axis=0))
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return out.astype(jnp.int8)


def _group_accumulate(x, wp, s, acc, *, gs: int, groups_per_blk: int,
                      w_bits: int, integer: bool, coarse: bool = False):
    """Shared block body for every fine-grained W{4,8}A8 kernel: unpack the
    packed weight block, run one MXU int8 matmul per group, scale-accumulate.

    ``integer=True`` keeps the accumulation in int32 (Eq. 2 — the
    integer-scale step, no convert in the loop); ``integer=False`` converts
    each group partial to f32 and FMAs with the float scale (Eq. 1 — the
    bottleneck the paper removes). ``coarse`` reuses scale row 0 for every
    group (per-channel baseline).
    """
    wfull = _unpack_wblock(wp, gs * groups_per_blk) if w_bits == 4 else wp
    for gi in range(groups_per_blk):  # static unroll over groups in block
        xg = x[:, gi * gs:(gi + 1) * gs]  # (bm, gs) int8
        wg = wfull[gi * gs:(gi + 1) * gs, :]
        part = jax.lax.dot_general(  # MXU int8 matmul, int32 out
            xg, wg, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        srow = s[0, :] if coarse else s[gi, :]
        if integer:
            # THE integer-scale step: stays in int32 — no convert in loop.
            acc = acc + part * srow[None, :]
        else:
            # THE float-scale bottleneck: per-group convert + f32 FMA.
            acc = acc + part.astype(jnp.float32) * srow[None, :]
    return acc


def _kernel(x_ref, wp_ref, s_ref, sa_ref, o_ref, acc_ref, *,
            nk: int, gs: int, groups_per_blk: int, w_bits: int,
            alpha: float, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = _group_accumulate(
        x_ref[...], wp_ref[...], s_ref[...], acc_ref[...],
        gs=gs, groups_per_blk=groups_per_blk, w_bits=w_bits, integer=True)

    @pl.when(k == nk - 1)
    def _epilogue():
        # ONE I32->F32 convert per output tile; /alpha folded into s_a.
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * (sa_ref[...] * (1.0 / alpha))
        ).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "alpha", "w_bits", "bm", "bn", "bk",
                     "interpret", "out_dtype"),
)
def fg_gemm_integer_scale(
    xq: jax.Array,        # int8 (M, K)
    sa: jax.Array,        # f32 (M, 1) per-token scales
    qvalue: jax.Array,    # int8 (K/2, N) packed (w4) | (K, N) (w8)
    int_scale: jax.Array, # int32 (K/g, N)
    *,
    group_size: int = 128,
    alpha: float = 1024.0,
    w_bits: int = 4,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    M, K = xq.shape
    N = qvalue.shape[1]
    gs = group_size
    if K % gs:
        raise ValueError(f"K={K} % group={gs}")
    bm = min(bm, _round_up(M, 8))
    bn = _snap_block(N, bn, 128)
    bk = _snap_block(K, min(bk, K), gs)
    if bk % gs:
        bk = gs  # block must hold whole groups
    nk = K // bk
    groups_per_blk = bk // gs

    Mp = _round_up(M, bm)
    if Mp != M:
        xq = jnp.pad(xq, ((0, Mp - M), (0, 0)))
        sa = jnp.pad(sa, ((0, Mp - M), (0, 0)))

    pack = 2 if w_bits == 4 else 1
    out = pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, gs=gs, groups_per_blk=groups_per_blk,
            w_bits=w_bits, alpha=alpha, out_dtype=out_dtype,
        ),
        grid=(Mp // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // pack, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((groups_per_blk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, qvalue, int_scale, sa)
    return out[:M]


def _snap_block(dim: int, blk: int, align: int) -> int:
    """Largest divisor of ``dim`` that is <= blk and a multiple of
    ``align`` (falling back to any divisor) — grids must tile exactly."""
    blk = min(blk, dim)
    if dim % blk == 0:
        return blk
    for cand in range(blk, 0, -1):
        if dim % cand == 0 and cand % align == 0:
            return cand
    for cand in range(blk, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _cdiv(a, b) * b
