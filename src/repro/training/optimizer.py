"""AdamW optimizer + LR schedules + global-norm clipping (pure JAX).

Optimizer state is declared from the same ParamSpec tree as the params, so
moments inherit the params' sharding (fully sharded optimizer states —
ZeRO-style — fall out of the FSDP rules for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import spec as S


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def state_specs(param_specs: Any) -> dict:
    """mu/nu in f32 with the same shapes+logical axes as the params."""

    def f32(s: S.ParamSpec) -> S.ParamSpec:
        return S.ParamSpec(s.shape, jnp.float32, "zeros", s.logical_axes)

    return {
        "mu": jax.tree.map(f32, param_specs, is_leaf=S.is_spec),
        "nu": jax.tree.map(f32, param_specs, is_leaf=S.is_spec),
        "step": S.ParamSpec((), jnp.int32, "zeros", ()),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params: Any, grads: Any, state: dict,
                  cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, mu, nu):
        mu_n = b1 * mu + (1 - b1) * g
        nu_n = b2 * nu + (1 - b2) * g * g
        mu_hat = mu_n / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu_n / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        pf = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), mu_n, nu_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
