"""Training step: loss / grad / AdamW update, pjit-ready.

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
that launch/train.py (and the dry-run) jits with in/out shardings derived
from the ParamSpec trees. Supports gradient accumulation (microbatching)
via an inner scan — the distributed-optimization knob that trades HBM for
step granularity at scale.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from . import optimizer as O


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token-level CE; logits f32 (B,S,V), labels int32 (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(api: ModelApi, cfg: ModelConfig, recipe=None):
    def loss_fn(params, batch):
        logits, _, aux = api.apply(
            params, cfg, batch["tokens"], recipe=recipe, mode="train",
            memory=batch.get("image_embeds", batch.get("frames")))
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(api: ModelApi, cfg: ModelConfig,
                    opt_cfg: O.AdamWConfig, recipe=None,
                    grad_accum: int = 1):
    loss_fn = make_loss_fn(api, cfg, recipe)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            # microbatch scan: split leading batch dim into grad_accum chunks
            def micro(carry, mb):
                acc = carry
                (lv, p), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   ((lv, p["ce"], p["aux"]), g))
                return acc, None

            def split(v):
                B = v.shape[0]
                return v.reshape(grad_accum, B // grad_accum, *v.shape[1:])

            mbs = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            init = ((jnp.float32(0), jnp.float32(0), jnp.float32(0)), zero)
            (sums, grads), _ = jax.lax.scan(micro, init, mbs)
            loss = sums[0] / grad_accum
            parts = {"ce": sums[1] / grad_accum, "aux": sums[2] / grad_accum}
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        new_params, new_state, om = O.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_state, metrics

    return train_step


def make_eval_step(api: ModelApi, cfg: ModelConfig, recipe=None):
    loss_fn = make_loss_fn(api, cfg, recipe)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return {"loss": loss, **parts}

    return eval_step
