"""Continuous-batching serving engine (vLLM-style slots, JAX-native).

Slot model: a fixed decode batch of ``max_slots`` sequences. New requests
prefill (padded to ``prefill_len``) into free slots; every engine tick runs
ONE batched decode step across all slots with per-slot positions; finished
sequences (eos / max_new) retire and free their slot. This is the
end-to-end path the paper accelerates: all linear layers inside run the
fine-grained quantized GEMMs when a recipe is attached.

Telemetry (repro.obs): every tick emits admit/prefill/decode/retire spans
into ``engine_phase_seconds{phase}`` plus a ``tick`` event carrying the
decode latency, slot occupancy, queue depth, and the rid occupying each
slot (``slot_rids`` — what places decode slices on per-request timeline
lanes); per request the engine observes TTFT (submit -> first token) and
TPOT (mean inter-token time) histograms and emits ``submit``/``admit``/
``retire`` lifecycle events threaded with a per-request ``trace_id``
(``eng<N>/r<rid>``). The jitted prefill/decode callables are wrapped in
``obs.device_timer`` — block_until_ready-bracketed, first (compile) call
excluded — populating ``engine_phase_device_seconds{phase}`` so host
overhead vs device compute is separable per phase. After each tick a
``counters`` event samples cumulative m-tile/qgemm counters for the
timeline's counter tracks. Jit retraces bump
``engine_traces_total{fn}`` and emit a ``trace`` event (the per-engine
``prefill_traces``/``decode_traces`` properties keep their exact PR-2
semantics — steady-state serving must hold decode at ONE trace, asserted
in tests). MoE routing records delivered by the ``models.moe`` sink are
folded into ``engine_moe_m_tiles_total{kind=executed|total}`` so ragged
skipping is continuously observable from the LIVE dispatch. All of it is
host-side at trace/tick boundaries — nothing records from inside the
jitted bodies (see ``repro.obs``).
"""
from __future__ import annotations

import dataclasses
import itertools
import weakref
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import moe
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from repro.nn import spec as S
from . import sampler

_PALLAS_MODES = ("pallas", "pallas_interpret")


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_seq: int = 256
    prefill_len: int = 64          # prompts padded/truncated to this
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: never stop early
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # qlinear backend for quantized layers inside prefill/decode:
    # "reference" | "pallas" | "pallas_interpret" | None (= keep the model
    # config's own kernel_mode). Carried onto ModelConfig.kernel_mode so
    # the jitted fns bake the chosen backend in — e.g. every expert FFN in
    # a quantized-MoE decode runs the ragged grouped kernel.
    kernel_mode: str | None = None


@dataclasses.dataclass
class _Slot:
    request_id: int = -1
    length: int = 0            # tokens currently in cache
    generated: list = dataclasses.field(default_factory=list)
    active: bool = False
    t_first: float = 0.0       # perf_counter at first generated token


class Engine:
    # process-wide engine numbering: per-request trace ids ("eng3/r7")
    # stay unique when several engines share one registry sequentially
    _ids = itertools.count()

    def __init__(self, api: ModelApi, cfg: ModelConfig, params: Any,
                 serve_cfg: ServeConfig, recipe=None):
        self.engine_id = f"eng{next(Engine._ids)}"
        self.api = api
        if serve_cfg.kernel_mode is not None:
            cfg = dataclasses.replace(cfg,
                                      kernel_mode=serve_cfg.kernel_mode)
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.recipe = recipe
        # trace counters: jit retraces bump these (the per-tick row_counts
        # of a quantized-MoE decode are traced operands, so steady-state
        # serving must keep decode_traces at 1 — asserted in tests). Kept
        # PER ENGINE (several engines may share one registry sequentially);
        # the registry additionally gets engine_traces_total + an event.
        self._trace_counts = {"prefill": 0, "decode": 0}
        B = serve_cfg.max_slots
        cspecs = api.cache_specs(cfg, B, serve_cfg.max_seq)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cspecs, is_leaf=S.is_spec)
        self.slots = [_Slot() for _ in range(B)]
        self.queue: list[tuple[int, list[int]]] = []
        self.outputs: dict[int, list[int]] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._steps = 0
        self._submit_t: dict[int, float] = {}
        # MoE routing sink: a WeakMethod, because the jitted closures below
        # capture ``self`` into reference cycles that delay __del__ — a
        # strong sink would pin retired engines alive in the global list.
        # Installed BEFORE the first trace so the callback gets staged.
        self._routing_buf: list[dict] = []
        self._routing_sink = weakref.WeakMethod(self._on_routing)
        moe.add_routing_sink(self._routing_sink)

        # jit'd single-request prefill (batch 1, fixed length).
        # mode="train" + cache: returns FULL-sequence logits (the engine
        # needs the logit at the true prompt end, which may be before the
        # padded end) while still populating the KV cache. mode="prefill"
        # keeps its last-token-only slicing for the serving dry-run.
        def prefill_fn(params, tokens, cache1):
            self._note_trace("prefill")
            logits, cache1, _ = self.api.apply(
                params, self.cfg, tokens, recipe=recipe, mode="train",
                cache=cache1, pos=0)
            return logits, cache1

        # device_timer wraps OUTSIDE jit (args pass through verbatim, so
        # the jit cache — and the one-decode-trace invariant — is
        # untouched); warmup=1 keeps the compile call out of the
        # steady-state *_device_seconds series.
        self._prefill = obs.device_timer(
            jax.jit(prefill_fn), "engine_phase_device_seconds",
            help="device time (block_until_ready) per engine phase",
            phase="prefill")

        # jit'd batched decode with per-slot positions
        def decode_fn(params, tokens, cache, pos_vec):
            self._note_trace("decode")
            logits, cache, _ = self.api.apply(
                params, self.cfg, tokens, recipe=recipe, mode="decode",
                cache=cache, pos=pos_vec)
            return logits[:, 0], cache

        self._decode = obs.device_timer(
            jax.jit(decode_fn), "engine_phase_device_seconds",
            help="device time (block_until_ready) per engine phase",
            phase="decode")
        self._cache1_specs = api.cache_specs(cfg, 1, serve_cfg.max_seq)
        # batch axis per cache leaf = position of "cache_batch" in the
        # spec's logical axes (scanned leaves lead with the LAYER axis)
        self._batch_axes = jax.tree.map(
            lambda s: (s.logical_axes.index("cache_batch")
                       if "cache_batch" in s.logical_axes else 0),
            cspecs, is_leaf=S.is_spec)
        # pre-create the headline series so snapshots show explicit zeros
        # even before the first tick
        reg = obs.current_registry()
        reg.counter("engine_ticks_total", "batched decode ticks")
        reg.counter("engine_tokens_total", "tokens decoded across slots")
        reg.counter("engine_requests_total", "request lifecycle events",
                    ("event",))
        reg.counter("engine_moe_m_tiles_total",
                    "MoE grouped-GEMM m-tiles from live routing: executed "
                    "(ragged skipping applied) vs dense total", ("kind",))

    # -- telemetry plumbing -------------------------------------------------
    def _note_trace(self, fn: str) -> None:
        """Runs at TRACE time inside the jitted closures (host python) —
        each execution of compiled code does NOT pass through here, which
        is exactly what makes it a retrace detector."""
        self._trace_counts[fn] += 1
        reg = obs.current_registry()
        reg.counter("engine_traces_total", "jit traces per engine function",
                    ("fn",)).inc(fn=fn)
        reg.emit({"ev": "trace", "fn": fn,
                  "engine_count": self._trace_counts[fn]})

    @property
    def prefill_traces(self) -> int:
        return self._trace_counts["prefill"]

    @property
    def decode_traces(self) -> int:
        return self._trace_counts["decode"]

    def _on_routing(self, rec: dict) -> None:
        self._routing_buf.append(rec)

    def _drain_routing(self) -> None:
        """Fold buffered MoE routing records (delivered host-side by
        jax.debug.callback during the forced computation) into the
        executed-vs-total m-tile counters. Ragged skipping only applies on
        the Pallas paths with a single dispatch group (G == 1) — other
        configurations execute densely."""
        if not self._routing_buf:
            return
        from repro.kernels.moe_gemm import ragged_tile_stats

        tiles = obs.current_registry().counter(
            "engine_moe_m_tiles_total", "", ("kind",))
        ragged_ok = self.cfg.kernel_mode in _PALLAS_MODES
        executed = total = 0
        buf, self._routing_buf = self._routing_buf, []
        for rec in buf:
            counts = rec["counts"]
            C = rec["capacity"]
            for g in range(counts.shape[0]):
                st = ragged_tile_stats([int(v) for v in counts[g]], C)
                total += st["dense_m_tiles"]
                executed += (st["ragged_m_tiles"]
                             if ragged_ok and counts.shape[0] == 1
                             else st["dense_m_tiles"])
        tiles.inc(executed, kind="executed")
        tiles.inc(total, kind="total")

    def _sample_counters(self, reg) -> None:
        """Emit one ``counters`` event per tick sampling the cumulative
        m-tile / qgemm counters — the timeline's counter tracks. Host-side
        at the tick boundary, after the routing drain."""
        tiles = reg.counter("engine_moe_m_tiles_total", "", ("kind",))
        calls = reg.counter(
            "qgemm_calls_total",
            "kernels.ops wrapper calls (trace-time under jit)",
            ("scheme", "kind", "shape", "block"))
        reg.emit({"ev": "counters", "tick": self._steps - 1,
                  "moe_executed": tiles.get(kind="executed"),
                  "moe_total": tiles.get(kind="total"),
                  "qgemm_calls": calls.total()})

    def close(self) -> None:
        """Detach the routing sink (tests / explicit lifecycle). Safe to
        skip: the WeakMethod is pruned automatically once the engine dies."""
        moe.remove_routing_sink(self._routing_sink)

    def trace_id(self, rid: int) -> str:
        """The per-request trace/span id threaded through lifecycle
        events (unique across engines within the process)."""
        return f"{self.engine_id}/r{rid}"

    # -- public API ------------------------------------------------------------
    def submit(self, prompt: list[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, list(prompt)))
        self._submit_t[rid] = obs.current_registry().now()
        obs.current_registry().emit(
            {"ev": "submit", "rid": rid, "trace_id": self.trace_id(rid),
             "prompt_len": len(prompt)})
        return rid

    def run(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        reg = obs.current_registry()
        while (self.queue or any(s.active for s in self.slots)) \
                and self._steps < max_ticks:
            with obs.span(reg, "engine_phase_seconds", phase="admit",
                          event="phase"):
                self._admit()
            self._tick()
        return dict(self.outputs)

    @property
    def ticks(self) -> int:
        return self._steps

    # -- internals ----------------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def _admit(self) -> None:
        reg = obs.current_registry()
        for i in self._free_slots():
            if not self.queue:
                break
            rid, prompt = self.queue.pop(0)
            with obs.span(reg, "engine_phase_seconds", phase="prefill",
                          event="admit") as sp:
                P = self.sc.prefill_len
                toks = (prompt[:P] + [0] * max(0, P - len(prompt)))
                true_len = min(len(prompt), P)
                cache1 = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype),
                    self._cache1_specs, is_leaf=S.is_spec)
                logits, cache1 = self._prefill(
                    self.params, jnp.asarray([toks], jnp.int32), cache1)

                # splice the prefilled slot into the batched cache along
                # each leaf's batch axis (scanned leaves lead with layers)
                def splice(C, c, ax):
                    idx = tuple([slice(None)] * ax + [i])
                    return C.at[idx].set(jnp.take(c, 0, axis=ax))

                self.cache = jax.tree.map(splice, self.cache, cache1,
                                          self._batch_axes)
                # token 0 must honor the sampling settings too — greedy
                # argmax here ignored temperature/top_k for the first token
                self._key, k = jax.random.split(self._key)
                first = int(np.asarray(sampler.sample(
                    logits[:, true_len - 1], k,
                    temperature=self.sc.temperature,
                    top_k=self.sc.top_k))[0])
                t_first = reg.now()
                self.slots[i] = _Slot(request_id=rid, length=true_len,
                                      generated=[first], active=True,
                                      t_first=t_first)
                sp.fields.update(rid=rid, slot=i, prompt_len=true_len,
                                 trace_id=self.trace_id(rid))
                t_sub = self._submit_t.pop(rid, None)
                if t_sub is not None:
                    ttft = t_first - t_sub
                    reg.histogram(
                        "engine_ttft_seconds",
                        "submit -> first generated token").observe(ttft)
                    sp.fields["ttft_s"] = round(ttft, 6)
            reg.counter("engine_requests_total", "", ("event",)).inc(
                event="admitted")
        self._drain_routing()

    def _tick(self) -> None:
        if not any(s.active for s in self.slots):
            return
        reg = obs.current_registry()
        B = self.sc.max_slots
        last = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = 0
        slot_rids = [-1] * B
        for i, s in enumerate(self.slots):
            if s.active:
                last[i, 0] = s.generated[-1]
                pos[i] = s.length
                slot_rids[i] = s.request_id
                active += 1
        with obs.span(reg, "engine_phase_seconds", phase="decode",
                      event="tick") as sp:
            self._key, k = jax.random.split(self._key)
            logits, self.cache = self._decode(
                self.params, jnp.asarray(last), self.cache,
                jnp.asarray(pos))
            nxt = sampler.sample(logits, k,
                                 temperature=self.sc.temperature,
                                 top_k=self.sc.top_k)
            nxt = np.asarray(nxt)  # forces the step (+ its callbacks)
            sp.fields.update(tick=self._steps, slots_active=active,
                             queue_depth=len(self.queue),
                             slot_rids=slot_rids)
        self._steps += 1
        reg.counter("engine_ticks_total", "").inc()
        reg.counter("engine_tokens_total", "").inc(active)
        self._drain_routing()
        self._sample_counters(reg)
        with obs.span(reg, "engine_phase_seconds", phase="retire",
                      event="phase"):
            for i, s in enumerate(self.slots):
                if not s.active:
                    continue
                s.length += 1
                tok = int(nxt[i])
                s.generated.append(tok)
                done = (tok == self.sc.eos_id
                        or len(s.generated) >= self.sc.max_new_tokens
                        or s.length + 1 >= self.sc.max_seq)
                if done:
                    self.outputs[s.request_id] = list(s.generated)
                    n = len(s.generated)
                    tpot = (reg.now() - s.t_first) / max(1, n - 1)
                    reg.histogram(
                        "engine_tpot_seconds",
                        "mean inter-token latency per request").observe(
                            tpot)
                    reg.counter("engine_requests_total", "",
                                ("event",)).inc(event="retired")
                    reg.emit({"ev": "retire", "rid": s.request_id,
                              "slot": i,
                              "trace_id": self.trace_id(s.request_id),
                              "tokens": n, "tpot_s": round(tpot, 6)})
                    self.slots[i] = _Slot()
        reg.gauge("engine_slots_active",
                  "occupied decode slots after retire").set(
                      sum(1 for s in self.slots if s.active))
        reg.gauge("engine_queue_depth", "requests waiting for a slot").set(
            len(self.queue))
