"""Continuous-batching serving engine (vLLM-style slots, JAX-native).

Slot model: a fixed decode batch of ``max_slots`` sequences. New requests
prefill (padded to ``prefill_len``) into free slots; every engine tick runs
ONE batched decode step across all slots with per-slot positions; finished
sequences (eos / max_new) retire and free their slot. This is the
end-to-end path the paper accelerates: all linear layers inside run the
fine-grained quantized GEMMs when a recipe is attached.

Request lifecycle / fault tolerance
-----------------------------------
Every submitted request ends in EXACTLY ONE terminal outcome::

    submitted -> rejected                 (queue full / over-length prompt)
              -> queued    -> cancelled   (Engine.cancel on a queued rid)
                           -> timeout     (deadline expired before a slot)
                           -> error       (engine aborted while queued)
              -> active    -> ok          (eos / max_new / max_seq)
                           -> cancelled   (Engine.cancel on an active rid)
                           -> timeout     (deadline expired mid-decode)
                           -> nan         (non-finite logits quarantined)
                           -> error       (prefill raised / engine aborted)

The conservation law — ``sum(engine_request_outcomes_total) ==
engine_requests_total{event="submitted"}`` once the engine drains — is a
hard invariant: outcomes are recorded through one chokepoint
(:meth:`Engine._finish`) that raises on a double retire, and
``benchmarks/regression.py`` enforces the law over benchmark metric
snapshots.

* **Backpressure**: ``ServeConfig.max_queue`` bounds the admission queue;
  surplus submits are *rejected* (terminal outcome, structured retire
  event) instead of growing an unbounded list.
* **Over-length prompts** are rejected at submit — never silently
  truncated — unless ``ServeConfig.truncate_prompts`` explicitly opts
  into clipping to ``prefill_len``.
* **Deadlines**: ``ServeConfig.deadline_s`` arms a per-request deadline
  (registry clock) checked host-side at tick boundaries, for queued and
  active requests alike; overruns retire with partial output.
* **NaN quarantine**: with ``ServeConfig.nan_guard`` (default on) decode
  logits are checked host-side — outside jit, per the ``repro.obs``
  cardinal rule — and only the poisoned slots retire with outcome
  ``nan``; co-batched requests continue bit-exact (each slot's token
  stream depends only on its own cache rows).
* **Circuit breaker / graceful degradation**: ``breaker_threshold``
  consecutive kernel-path exceptions (prefill/decode), or that many
  consecutive poisoned decode ticks, trip a fallback — the engine swaps
  ``kernel_mode`` to ``ServeConfig.fallback_kernel_mode`` (e.g.
  ``pallas -> reference``) and, when ``fallback_params``/
  ``fallback_recipe`` were provided at construction, the quantized
  parameter set too (integer-scale -> float-scale, the DGQ-style
  two-tier degradation), then RE-ESTABLISHES the jitted prefill/decode.
  Each fallback is one intentional extra trace — steady state must still
  hold ``decode_traces == 1 + fallbacks``. ``engine_fallback_events_total
  {reason}`` counts trips; with no fallback remaining the engine aborts:
  every in-flight request retires with outcome ``error`` (no slot stays
  active) and :class:`EngineAborted` propagates so the driver's
  ``finally`` can flush telemetry. External quant-health monitors (e.g.
  watching ``alpha_cap_events_total`` / ``qcert_verdicts_total{verdict=
  "fallback"}`` deltas) can force the same path via
  :meth:`Engine.trip_breaker`.
* **Tick watchdog**: a ``distributed.fault.Heartbeat`` on the registry
  clock times every decode tick; stragglers (> ``slow_tick_factor`` x
  rolling median) bump ``engine_slow_ticks_total`` + a ``slow_tick``
  event (a timeline marker).

Fault injection for all of the above lives in ``repro.serving.chaos``
(deterministic NaN / kernel-exception / slow-tick / queue-flood
injection, driving the ``pytest -m chaos`` suite).

Telemetry (repro.obs): every tick emits admit/prefill/decode/retire spans
into ``engine_phase_seconds{phase}`` plus a ``tick`` event carrying the
decode latency, slot occupancy, queue depth, and the rid occupying each
slot (``slot_rids`` — what places decode slices on per-request timeline
lanes); per request the engine observes TTFT (submit -> first token) and
TPOT (mean inter-token time) histograms and emits ``submit``/``admit``/
``retire`` lifecycle events threaded with a per-request ``trace_id``
(``eng<N>/r<rid>``); retire events carry the terminal ``outcome``, which
``engine_request_outcomes_total{outcome}`` counts. The jitted
prefill/decode callables are wrapped in ``obs.device_timer`` —
block_until_ready-bracketed, first (compile) call excluded — populating
``engine_phase_device_seconds{phase}`` so host overhead vs device compute
is separable per phase. After each tick a ``counters`` event samples
cumulative m-tile/qgemm counters for the timeline's counter tracks. Jit
retraces bump ``engine_traces_total{fn}`` and emit a ``trace`` event (the
per-engine ``prefill_traces``/``decode_traces`` properties keep their
exact PR-2 semantics — steady-state serving must hold decode at ONE trace
per established kernel route, asserted in tests). MoE routing records
delivered by the ``models.moe`` sink are folded into
``engine_moe_m_tiles_total{kind=executed|total}`` so ragged skipping is
continuously observable from the LIVE dispatch. All of it is host-side at
trace/tick boundaries — nothing records from inside the jitted bodies
(see ``repro.obs``).
"""
from __future__ import annotations

import dataclasses
import itertools
import weakref
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.distributed.fault import Heartbeat, HeartbeatConfig
from repro.models import moe
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from repro.nn import spec as S
from . import sampler

_PALLAS_MODES = ("pallas", "pallas_interpret")

#: The terminal request outcomes (the state machine's accepting states).
OUTCOMES = ("ok", "timeout", "cancelled", "rejected", "nan", "error")


class EngineAborted(RuntimeError):
    """The circuit breaker exhausted every fallback: the engine quiesced
    (all in-flight requests retired with outcome ``error``, no slot left
    active) and refuses further ticks. Telemetry flushed by the caller's
    ``finally`` still contains the full event log."""


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_seq: int = 256
    prefill_len: int = 64          # prompts padded to this length
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: never stop early
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # qlinear backend for quantized layers inside prefill/decode:
    # "reference" | "pallas" | "pallas_interpret" | None (= keep the model
    # config's own kernel_mode). Carried onto ModelConfig.kernel_mode so
    # the jitted fns bake the chosen backend in — e.g. every expert FFN in
    # a quantized-MoE decode runs the ragged grouped kernel.
    kernel_mode: str | None = None
    # -- robustness ---------------------------------------------------------
    max_queue: int = 0             # admission queue bound; 0 = unbounded
    deadline_s: float = 0.0        # per-request deadline; 0 = none
    truncate_prompts: bool = False  # opt-in: clip over-length prompts
    nan_guard: bool = True         # host-side NaN/Inf logit quarantine
    breaker_threshold: int = 3     # consecutive failures tripping fallback
    # kernel_mode the breaker degrades to (None disables mode fallback;
    # a value equal to the active mode is ignored)
    fallback_kernel_mode: str | None = "reference"
    slow_tick_factor: float = 3.0  # watchdog straggler multiple of median


@dataclasses.dataclass
class _Slot:
    request_id: int = -1
    length: int = 0            # tokens currently in cache
    generated: list = dataclasses.field(default_factory=list)
    active: bool = False
    t_first: float = 0.0       # perf_counter at first generated token


class Engine:
    # process-wide engine numbering: per-request trace ids ("eng3/r7")
    # stay unique when several engines share one registry sequentially
    _ids = itertools.count()

    def __init__(self, api: ModelApi, cfg: ModelConfig, params: Any,
                 serve_cfg: ServeConfig, recipe=None, *,
                 fallback_params: Any = None, fallback_recipe=None):
        self.engine_id = f"eng{next(Engine._ids)}"
        self.api = api
        if serve_cfg.kernel_mode is not None:
            cfg = dataclasses.replace(cfg,
                                      kernel_mode=serve_cfg.kernel_mode)
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.recipe = recipe
        # trace counters: jit retraces bump these (the per-tick row_counts
        # of a quantized-MoE decode are traced operands, so steady-state
        # serving must keep decode_traces at 1 per established route —
        # asserted in tests). Kept PER ENGINE (several engines may share
        # one registry sequentially); the registry additionally gets
        # engine_traces_total + an event.
        self._trace_counts = {"prefill": 0, "decode": 0}
        B = serve_cfg.max_slots
        cspecs = api.cache_specs(cfg, B, serve_cfg.max_seq)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cspecs, is_leaf=S.is_spec)
        self.slots = [_Slot() for _ in range(B)]
        self.queue: list[tuple[int, list[int]]] = []
        self.outputs: dict[int, list[int]] = {}
        #: rid -> terminal outcome (exactly one entry per finished request)
        self.outcomes: dict[int, str] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._steps = 0
        self._submit_t: dict[int, float] = {}
        self._deadlines: dict[int, float] = {}
        self._closed = False
        # circuit-breaker state
        self._fail_streak = 0      # consecutive prefill/decode exceptions
        self._nan_streak = 0       # consecutive poisoned decode ticks
        self._fallbacks = 0
        fb = serve_cfg.fallback_kernel_mode
        self._fallback_modes = [fb] if fb and fb != cfg.kernel_mode else []
        self._fallback_params = fallback_params
        self._fallback_recipe = fallback_recipe
        # host-side wrappers (chaos injection) re-applied on every jit
        # re-establishment — see add_decode_wrapper
        self._decode_wrappers: list = []
        # tick watchdog on the registry clock (deterministic under a fake
        # clock); stragglers surface as engine_slow_ticks_total + events
        self._watchdog = Heartbeat(
            HeartbeatConfig(straggler_factor=serve_cfg.slow_tick_factor),
            on_straggler=self._on_slow_tick,
            clock=lambda: obs.current_registry().now())
        # MoE routing sink: a WeakMethod, because the jitted closures below
        # capture ``self`` into reference cycles that delay __del__ — a
        # strong sink would pin retired engines alive in the global list.
        # Installed BEFORE the first trace so the callback gets staged.
        self._routing_buf: list[dict] = []
        self._routing_sink = weakref.WeakMethod(self._on_routing)
        moe.add_routing_sink(self._routing_sink)

        self._build_jit_fns()
        self._cache1_specs = api.cache_specs(cfg, 1, serve_cfg.max_seq)
        # batch axis per cache leaf = position of "cache_batch" in the
        # spec's logical axes (scanned leaves lead with the LAYER axis)
        self._batch_axes = jax.tree.map(
            lambda s: (s.logical_axes.index("cache_batch")
                       if "cache_batch" in s.logical_axes else 0),
            cspecs, is_leaf=S.is_spec)
        # pre-create the headline series so snapshots show explicit zeros
        # even before the first tick (every outcome series included: the
        # conservation law is checkable from any snapshot)
        reg = obs.current_registry()
        reg.counter("engine_ticks_total", "batched decode ticks")
        reg.counter("engine_tokens_total", "tokens decoded across slots")
        reg.counter("engine_requests_total", "request lifecycle events",
                    ("event",))
        reg.counter("engine_moe_m_tiles_total",
                    "MoE grouped-GEMM m-tiles from live routing: executed "
                    "(ragged skipping applied) vs dense total", ("kind",))
        out = reg.counter("engine_request_outcomes_total",
                          "terminal per-request outcomes (conservation: "
                          "sums to submitted once drained)", ("outcome",))
        for o in OUTCOMES:
            out.inc(0, outcome=o)
        reg.counter("engine_fallback_events_total",
                    "circuit-breaker kernel-route fallbacks", ("reason",))
        reg.counter("engine_kernel_failures_total",
                    "exceptions from the jitted prefill/decode path",
                    ("phase",))
        reg.counter("engine_slow_ticks_total",
                    "watchdog: decode ticks slower than "
                    "slow_tick_factor x rolling median").inc(0)

    # -- jit establishment --------------------------------------------------
    def _build_jit_fns(self) -> None:
        """(Re-)establish the jitted prefill/decode closures from the
        CURRENT ``self.cfg`` / ``self.recipe`` — called at construction
        and again by the circuit breaker after a kernel-route fallback
        (each re-establishment is one intentional extra trace)."""
        recipe = self.recipe

        # jit'd single-request prefill (batch 1, fixed length).
        # mode="train" + cache: returns FULL-sequence logits (the engine
        # needs the logit at the true prompt end, which may be before the
        # padded end) while still populating the KV cache. mode="prefill"
        # keeps its last-token-only slicing for the serving dry-run.
        def prefill_fn(params, tokens, cache1):
            self._note_trace("prefill")
            logits, cache1, _ = self.api.apply(
                params, self.cfg, tokens, recipe=recipe, mode="train",
                cache=cache1, pos=0)
            return logits, cache1

        # device_timer wraps OUTSIDE jit (args pass through verbatim, so
        # the jit cache — and the one-decode-trace invariant — is
        # untouched); warmup=1 keeps the compile call out of the
        # steady-state *_device_seconds series.
        self._prefill = obs.device_timer(
            jax.jit(prefill_fn), "engine_phase_device_seconds",
            help="device time (block_until_ready) per engine phase",
            phase="prefill")

        # jit'd batched decode with per-slot positions
        def decode_fn(params, tokens, cache, pos_vec):
            self._note_trace("decode")
            logits, cache, _ = self.api.apply(
                params, self.cfg, tokens, recipe=recipe, mode="decode",
                cache=cache, pos=pos_vec)
            return logits[:, 0], cache

        self._decode_base = obs.device_timer(
            jax.jit(decode_fn), "engine_phase_device_seconds",
            help="device time (block_until_ready) per engine phase",
            phase="decode")
        self._rewrap_decode()

    def _rewrap_decode(self) -> None:
        fn = self._decode_base
        for wrap in self._decode_wrappers:
            fn = wrap(fn)
        self._decode = fn

    def add_decode_wrapper(self, wrap) -> None:
        """Install a host-side ``fn -> fn`` wrapper around the jitted
        decode callable (fault injection, extra instrumentation). The
        wrapper composes OUTSIDE jit on concrete arrays — it cannot
        retrace — and is re-applied automatically when the circuit
        breaker re-establishes decode. ``repro.serving.chaos`` is the
        canonical client."""
        self._decode_wrappers.append(wrap)
        self._rewrap_decode()

    # -- telemetry plumbing -------------------------------------------------
    def _note_trace(self, fn: str) -> None:
        """Runs at TRACE time inside the jitted closures (host python) —
        each execution of compiled code does NOT pass through here, which
        is exactly what makes it a retrace detector."""
        self._trace_counts[fn] += 1
        reg = obs.current_registry()
        reg.counter("engine_traces_total", "jit traces per engine function",
                    ("fn",)).inc(fn=fn)
        reg.emit({"ev": "trace", "fn": fn,
                  "engine_count": self._trace_counts[fn]})

    @property
    def prefill_traces(self) -> int:
        return self._trace_counts["prefill"]

    @property
    def decode_traces(self) -> int:
        return self._trace_counts["decode"]

    @property
    def fallbacks(self) -> int:
        """Circuit-breaker fallback count: steady-state decode must hold
        ``decode_traces == 1 + fallbacks``."""
        return self._fallbacks

    def _on_routing(self, rec: dict) -> None:
        self._routing_buf.append(rec)

    def _on_slow_tick(self, step: int, dt: float, med: float) -> None:
        reg = obs.current_registry()
        reg.counter("engine_slow_ticks_total", "").inc()
        reg.emit({"ev": "slow_tick", "tick": step,
                  "seconds": round(dt, 6), "median_s": round(med, 6)})

    def _drain_routing(self) -> None:
        """Fold buffered MoE routing records (delivered host-side by
        jax.debug.callback during the forced computation) into the
        executed-vs-total m-tile counters. Ragged skipping only applies on
        the Pallas paths with a single dispatch group (G == 1) — other
        configurations execute densely."""
        if not self._routing_buf:
            return
        from repro.kernels.moe_gemm import ragged_tile_stats

        tiles = obs.current_registry().counter(
            "engine_moe_m_tiles_total", "", ("kind",))
        ragged_ok = self.cfg.kernel_mode in _PALLAS_MODES
        executed = total = 0
        buf, self._routing_buf = self._routing_buf, []
        for rec in buf:
            counts = rec["counts"]
            C = rec["capacity"]
            for g in range(counts.shape[0]):
                st = ragged_tile_stats([int(v) for v in counts[g]], C)
                total += st["dense_m_tiles"]
                executed += (st["ragged_m_tiles"]
                             if ragged_ok and counts.shape[0] == 1
                             else st["dense_m_tiles"])
        tiles.inc(executed, kind="executed")
        tiles.inc(total, kind="total")

    def _sample_counters(self, reg) -> None:
        """Emit one ``counters`` event per tick sampling the cumulative
        m-tile / qgemm counters — the timeline's counter tracks. Host-side
        at the tick boundary, after the routing drain."""
        tiles = reg.counter("engine_moe_m_tiles_total", "", ("kind",))
        calls = reg.counter(
            "qgemm_calls_total",
            "kernels.ops wrapper calls (trace-time under jit)",
            ("scheme", "kind", "shape", "block"))
        reg.emit({"ev": "counters", "tick": self._steps - 1,
                  "moe_executed": tiles.get(kind="executed"),
                  "moe_total": tiles.get(kind="total"),
                  "qgemm_calls": calls.total()})

    def close(self) -> None:
        """Detach the routing sink (tests / explicit lifecycle).
        Idempotent; safe to skip entirely — the WeakMethod is pruned
        automatically once the engine dies."""
        if self._closed:
            return
        self._closed = True
        moe.remove_routing_sink(self._routing_sink)

    def trace_id(self, rid: int) -> str:
        """The per-request trace/span id threaded through lifecycle
        events (unique across engines within the process)."""
        return f"{self.engine_id}/r{rid}"

    # -- public API ------------------------------------------------------------
    def submit(self, prompt: list[int]) -> int:
        """Enqueue a request. ALWAYS returns a rid; requests refused by
        admission control (bounded queue, over-length prompt) are
        immediately terminal with outcome ``rejected`` — check
        :meth:`outcome`."""
        rid = self._next_id
        self._next_id += 1
        reg = obs.current_registry()
        reg.counter("engine_requests_total", "", ("event",)).inc(
            event="submitted")
        self._submit_t[rid] = reg.now()
        reg.emit(
            {"ev": "submit", "rid": rid, "trace_id": self.trace_id(rid),
             "prompt_len": len(prompt)})
        if len(prompt) > self.sc.prefill_len and not self.sc.truncate_prompts:
            self._finish(rid, "rejected", reason="prompt_overlength",
                         prompt_len=len(prompt))
            return rid
        if self.sc.max_queue and len(self.queue) >= self.sc.max_queue:
            self._finish(rid, "rejected", reason="queue_full",
                         queue_depth=len(self.queue))
            return rid
        if self.sc.deadline_s > 0:
            self._deadlines[rid] = self._submit_t[rid] + self.sc.deadline_s
        self.queue.append((rid, list(prompt)))
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or active request (terminal outcome
        ``cancelled``; any tokens generated so far are delivered in
        ``outputs``). Returns False for unknown or already-terminal
        rids."""
        if rid in self.outcomes or not 0 <= rid < self._next_id:
            return False
        for j, (qrid, _) in enumerate(self.queue):
            if qrid == rid:
                del self.queue[j]
                self._finish(rid, "cancelled")
                return True
        for i, s in enumerate(self.slots):
            if s.active and s.request_id == rid:
                self._finish(rid, "cancelled", slot=i, output=s.generated,
                             tokens=len(s.generated))
                self.slots[i] = _Slot()
                return True
        return False

    def outcome(self, rid: int) -> str | None:
        """Terminal outcome for ``rid`` (None while still in flight)."""
        return self.outcomes.get(rid)

    def trip_breaker(self, reason: str) -> None:
        """Force a circuit-breaker trip (external quant-health monitors —
        e.g. alarming on ``alpha_cap_events_total`` /
        ``qcert_verdicts_total{verdict="fallback"}`` deltas). Falls back
        if a route remains, else aborts the engine."""
        self._trip_breaker(reason)

    def run(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        reg = obs.current_registry()
        try:
            while (self.queue or any(s.active for s in self.slots)) \
                    and self._steps < max_ticks:
                self._expire_queued()
                with obs.span(reg, "engine_phase_seconds", phase="admit",
                              event="phase"):
                    self._admit()
                self._tick()
        except Exception:
            # a crashed run leaves no slot marked active and every
            # in-flight request with a terminal outcome — the driver's
            # ``finally`` can still flush a conserved metrics snapshot
            self._quiesce("error")
            raise
        return dict(self.outputs)

    @property
    def ticks(self) -> int:
        return self._steps

    # -- request state machine ---------------------------------------------
    def _finish(self, rid: int, outcome: str, *, slot: int | None = None,
                output: list | None = None, **fields) -> None:
        """The SINGLE chokepoint recording a terminal outcome: outcome
        map + counter + structured retire event. Raises on a second
        retire of the same rid (the conservation law's no-double-retire
        half)."""
        if rid in self.outcomes:
            raise RuntimeError(
                f"request {rid} already terminal "
                f"({self.outcomes[rid]!r}); double retire as {outcome!r}")
        self.outcomes[rid] = outcome
        self._submit_t.pop(rid, None)
        self._deadlines.pop(rid, None)
        if output is not None:
            self.outputs[rid] = list(output)
        reg = obs.current_registry()
        reg.counter("engine_request_outcomes_total", "", ("outcome",)).inc(
            outcome=outcome)
        ev = {"ev": "retire", "rid": rid, "outcome": outcome,
              "trace_id": self.trace_id(rid), **fields}
        if slot is not None:
            ev["slot"] = slot
        reg.emit(ev)

    def _quiesce(self, outcome: str) -> None:
        """Drive every in-flight request to a terminal outcome and free
        all slots (abort / crashed-run path). Idempotent per rid."""
        for i, s in enumerate(self.slots):
            if s.active and s.request_id not in self.outcomes:
                self._finish(s.request_id, outcome, slot=i,
                             output=s.generated, tokens=len(s.generated))
            self.slots[i] = _Slot()
        for rid, _ in self.queue:
            if rid not in self.outcomes:
                self._finish(rid, outcome)
        self.queue.clear()

    def _expire_queued(self) -> None:
        """Retire queued requests whose deadline passed before a slot
        freed up (they never prefill)."""
        if not self._deadlines or not self.queue:
            return
        now = obs.current_registry().now()
        keep = []
        for rid, prompt in self.queue:
            dl = self._deadlines.get(rid)
            if dl is not None and now > dl:
                self._finish(rid, "timeout", where="queued")
            else:
                keep.append((rid, prompt))
        self.queue[:] = keep

    # -- circuit breaker ----------------------------------------------------
    def _on_phase_failure(self, phase: str, exc: Exception,
                          rid: int | None = None) -> None:
        """A kernel-path exception escaped the jitted ``phase``: count it,
        retire the directly-affected rid (prefill only — decode failures
        leave slots intact for the retry), and trip the breaker when the
        streak reaches the threshold."""
        self._fail_streak += 1
        reg = obs.current_registry()
        reg.counter("engine_kernel_failures_total", "", ("phase",)).inc(
            phase=phase)
        ev = {"ev": "kernel_failure", "phase": phase,
              "streak": self._fail_streak, "error": repr(exc)[:200]}
        if rid is not None:
            ev["rid"] = rid
        reg.emit(ev)
        if rid is not None:
            self._finish(rid, "error", error=repr(exc)[:200])
        if self._fail_streak >= max(1, self.sc.breaker_threshold):
            self._trip_breaker(f"{phase}_exception", exc)

    def _fallback_available(self) -> bool:
        return bool(self._fallback_modes) \
            or self._fallback_params is not None

    def _trip_breaker(self, reason: str, exc: Exception | None = None):
        if self._fallback_available():
            self._fallback(reason)
        else:
            self._abort(reason, exc)

    def _fallback(self, reason: str) -> None:
        """Graceful degradation: swap to the fallback kernel route (and
        parameter set, when provided), reset breaker state, and
        re-establish the jitted prefill/decode (ONE intentional extra
        trace, surfaced via ``fallbacks``)."""
        reg = obs.current_registry()
        frm = self.cfg.kernel_mode
        if self._fallback_modes:
            to = self._fallback_modes.pop(0)
            self.cfg = dataclasses.replace(self.cfg, kernel_mode=to)
        else:
            to = frm
        swapped = self._fallback_params is not None
        if swapped:
            self.params, self._fallback_params = self._fallback_params, None
            self.recipe, self._fallback_recipe = self._fallback_recipe, None
        self._fallbacks += 1
        self._fail_streak = 0
        self._nan_streak = 0
        reg.counter("engine_fallback_events_total", "", ("reason",)).inc(
            reason=reason)
        reg.emit({"ev": "fallback", "reason": reason, "from": str(frm),
                  "to": str(to), "params_swapped": swapped,
                  "fallbacks": self._fallbacks})
        self._build_jit_fns()

    def _abort(self, reason: str, exc: Exception | None = None):
        reg = obs.current_registry()
        reg.emit({"ev": "abort", "reason": reason,
                  "error": repr(exc)[:200] if exc else None})
        self._quiesce("error")
        raise EngineAborted(
            f"{self.engine_id}: breaker tripped ({reason}) with no "
            f"fallback route remaining") from exc

    # -- internals ----------------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def _admit(self) -> None:
        reg = obs.current_registry()
        for i in self._free_slots():
            if not self.queue:
                break
            rid, prompt = self.queue.pop(0)
            poisoned = False
            try:
                with obs.span(reg, "engine_phase_seconds", phase="prefill",
                              event="admit") as sp:
                    P = self.sc.prefill_len
                    # over-length prompts were rejected at submit unless
                    # truncate_prompts explicitly opted into this clip
                    toks = (prompt[:P] + [0] * max(0, P - len(prompt)))
                    true_len = min(len(prompt), P)
                    cache1 = jax.tree.map(
                        lambda s: jnp.zeros(s.shape, s.dtype),
                        self._cache1_specs, is_leaf=S.is_spec)
                    logits, cache1 = self._prefill(
                        self.params, jnp.asarray([toks], jnp.int32), cache1)

                    # splice the prefilled slot into the batched cache
                    # along each leaf's batch axis (scanned leaves lead
                    # with layers)
                    def splice(C, c, ax):
                        idx = tuple([slice(None)] * ax + [i])
                        return C.at[idx].set(jnp.take(c, 0, axis=ax))

                    self.cache = jax.tree.map(splice, self.cache, cache1,
                                              self._batch_axes)
                    first_row = logits[:, true_len - 1]
                    if self.sc.nan_guard and \
                            not np.isfinite(np.asarray(first_row)).all():
                        poisoned = True
                        sp.fields.update(rid=rid, slot=i, outcome="nan")
                    else:
                        # token 0 must honor the sampling settings too —
                        # greedy argmax here ignored temperature/top_k for
                        # the first token
                        self._key, k = jax.random.split(self._key)
                        first = int(np.asarray(sampler.sample(
                            first_row, k,
                            temperature=self.sc.temperature,
                            top_k=self.sc.top_k))[0])
                        t_first = reg.now()
                        self.slots[i] = _Slot(request_id=rid,
                                              length=true_len,
                                              generated=[first],
                                              active=True, t_first=t_first)
                        sp.fields.update(rid=rid, slot=i,
                                         prompt_len=true_len,
                                         trace_id=self.trace_id(rid))
                        t_sub = self._submit_t.get(rid)
                        if t_sub is not None:
                            ttft = t_first - t_sub
                            reg.histogram(
                                "engine_ttft_seconds",
                                "submit -> first generated token",
                            ).observe(ttft)
                            sp.fields["ttft_s"] = round(ttft, 6)
            except Exception as exc:
                self._on_phase_failure("prefill", exc, rid=rid)
                continue
            if poisoned:
                self._finish(rid, "nan", slot=i, output=[],
                             where="prefill")
                continue
            self._fail_streak = 0
            reg.counter("engine_requests_total", "", ("event",)).inc(
                event="admitted")
        self._drain_routing()

    def _tick(self) -> None:
        if not any(s.active for s in self.slots):
            return
        reg = obs.current_registry()
        B = self.sc.max_slots
        last = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = 0
        slot_rids = [-1] * B
        for i, s in enumerate(self.slots):
            if s.active:
                last[i, 0] = s.generated[-1]
                pos[i] = s.length
                slot_rids[i] = s.request_id
                active += 1
        try:
            with obs.span(reg, "engine_phase_seconds", phase="decode",
                          event="tick") as sp:
                self._watchdog.start()
                logits, new_cache = self._decode(
                    self.params, jnp.asarray(last), self.cache,
                    jnp.asarray(pos))
                # host-side numeric guard (outside jit): one transfer of
                # the (B, V) logits, reused for per-slot quarantine below
                lg = np.asarray(logits) if self.sc.nan_guard else None
                # the key splits AFTER decode succeeds, so failed attempts
                # never advance the sampling stream (retries stay
                # bit-exact vs a fault-free run)
                self._key, k = jax.random.split(self._key)
                nxt = np.asarray(sampler.sample(
                    logits, k, temperature=self.sc.temperature,
                    top_k=self.sc.top_k))  # forces the step (+ callbacks)
                self._watchdog.stop(self._steps)
                sp.fields.update(tick=self._steps, slots_active=active,
                                 queue_depth=len(self.queue),
                                 slot_rids=slot_rids)
        except Exception as exc:
            # tick NOT advanced, cache NOT committed: the run loop retries
            # (bounded — the breaker trips fallback/abort on a streak)
            self._on_phase_failure("decode", exc)
            return
        self.cache = new_cache
        self._fail_streak = 0
        bad = {i for i, s in enumerate(self.slots)
               if s.active and lg is not None
               and not np.isfinite(lg[i]).all()}
        if bad:
            self._nan_streak += 1
        else:
            self._nan_streak = 0
        self._steps += 1
        reg.counter("engine_ticks_total", "").inc()
        reg.counter("engine_tokens_total", "").inc(active - len(bad))
        self._drain_routing()
        self._sample_counters(reg)
        with obs.span(reg, "engine_phase_seconds", phase="retire",
                      event="phase"):
            now = reg.now()
            for i, s in enumerate(self.slots):
                if not s.active:
                    continue
                rid = s.request_id
                if i in bad:
                    # quarantine: ONLY the poisoned slot retires; its
                    # garbage token is never appended, co-batched slots
                    # proceed bit-exact (row-isolated computation)
                    self._finish(rid, "nan", slot=i, output=s.generated,
                                 tokens=len(s.generated))
                    self.slots[i] = _Slot()
                    continue
                s.length += 1
                tok = int(nxt[i])
                s.generated.append(tok)
                done = (tok == self.sc.eos_id
                        or len(s.generated) >= self.sc.max_new_tokens
                        or s.length + 1 >= self.sc.max_seq)
                dl = self._deadlines.get(rid)
                if done:
                    n = len(s.generated)
                    tpot = (now - s.t_first) / max(1, n - 1)
                    reg.histogram(
                        "engine_tpot_seconds",
                        "mean inter-token latency per request").observe(
                            tpot)
                    reg.counter("engine_requests_total", "",
                                ("event",)).inc(event="retired")
                    self._finish(rid, "ok", slot=i, output=s.generated,
                                 tokens=n, tpot_s=round(tpot, 6))
                    self.slots[i] = _Slot()
                elif dl is not None and now > dl:
                    self._finish(rid, "timeout", slot=i,
                                 output=s.generated,
                                 tokens=len(s.generated))
                    self.slots[i] = _Slot()
        reg.gauge("engine_slots_active",
                  "occupied decode slots after retire").set(
                      sum(1 for s in self.slots if s.active))
        reg.gauge("engine_queue_depth", "requests waiting for a slot").set(
            len(self.queue))
        if self._nan_streak >= max(1, self.sc.breaker_threshold):
            # persistent poisoned logits = quant-health alarm: degrade to
            # the fallback route instead of burning ticks on NaNs
            self._trip_breaker("nan_logits")
