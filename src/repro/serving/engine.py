"""Continuous-batching serving engine (vLLM-style slots, JAX-native).

Slot model: a fixed decode batch of ``max_slots`` sequences. New requests
prefill (padded to ``prefill_len``) into free slots; every engine tick runs
ONE batched decode step across all slots with per-slot positions; finished
sequences (eos / max_new) retire and free their slot. This is the
end-to-end path the paper accelerates: all linear layers inside run the
fine-grained quantized GEMMs when a recipe is attached.

Scale note: on a real mesh the cache lives sharded (cache_batch -> data,
cache_seq -> model) and this same engine drives pjit'd prefill/decode fns;
here it runs CPU-sized models end-to-end for the examples and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from repro.nn import spec as S
from . import sampler


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_seq: int = 256
    prefill_len: int = 64          # prompts padded/truncated to this
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: never stop early
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # qlinear backend for quantized layers inside prefill/decode:
    # "reference" | "pallas" | "pallas_interpret" | None (= keep the model
    # config's own kernel_mode). Carried onto ModelConfig.kernel_mode so
    # the jitted fns bake the chosen backend in — e.g. every expert FFN in
    # a quantized-MoE decode runs the ragged grouped kernel.
    kernel_mode: str | None = None


@dataclasses.dataclass
class _Slot:
    request_id: int = -1
    length: int = 0            # tokens currently in cache
    generated: list = dataclasses.field(default_factory=list)
    active: bool = False


class Engine:
    def __init__(self, api: ModelApi, cfg: ModelConfig, params: Any,
                 serve_cfg: ServeConfig, recipe=None):
        self.api = api
        if serve_cfg.kernel_mode is not None:
            cfg = dataclasses.replace(cfg,
                                      kernel_mode=serve_cfg.kernel_mode)
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.recipe = recipe
        # trace counters: jit retraces bump these (the per-tick row_counts
        # of a quantized-MoE decode are traced operands, so steady-state
        # serving must keep decode_traces at 1 — asserted in tests).
        self.prefill_traces = 0
        self.decode_traces = 0
        B = serve_cfg.max_slots
        cspecs = api.cache_specs(cfg, B, serve_cfg.max_seq)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cspecs, is_leaf=S.is_spec)
        self.slots = [_Slot() for _ in range(B)]
        self.queue: list[tuple[int, list[int]]] = []
        self.outputs: dict[int, list[int]] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._steps = 0

        # jit'd single-request prefill (batch 1, fixed length).
        # mode="train" + cache: returns FULL-sequence logits (the engine
        # needs the logit at the true prompt end, which may be before the
        # padded end) while still populating the KV cache. mode="prefill"
        # keeps its last-token-only slicing for the serving dry-run.
        def prefill_fn(params, tokens, cache1):
            self.prefill_traces += 1
            logits, cache1, _ = self.api.apply(
                params, self.cfg, tokens, recipe=recipe, mode="train",
                cache=cache1, pos=0)
            return logits, cache1

        self._prefill = jax.jit(prefill_fn)

        # jit'd batched decode with per-slot positions
        def decode_fn(params, tokens, cache, pos_vec):
            self.decode_traces += 1
            logits, cache, _ = self.api.apply(
                params, self.cfg, tokens, recipe=recipe, mode="decode",
                cache=cache, pos=pos_vec)
            return logits[:, 0], cache

        self._decode = jax.jit(decode_fn)
        self._cache1_specs = api.cache_specs(cfg, 1, serve_cfg.max_seq)
        # batch axis per cache leaf = position of "cache_batch" in the
        # spec's logical axes (scanned leaves lead with the LAYER axis)
        self._batch_axes = jax.tree.map(
            lambda s: (s.logical_axes.index("cache_batch")
                       if "cache_batch" in s.logical_axes else 0),
            cspecs, is_leaf=S.is_spec)

    # -- public API ------------------------------------------------------------
    def submit(self, prompt: list[int]) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, list(prompt)))
        return rid

    def run(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        while (self.queue or any(s.active for s in self.slots)) \
                and self._steps < max_ticks:
            self._admit()
            self._tick()
        return dict(self.outputs)

    @property
    def ticks(self) -> int:
        return self._steps

    # -- internals ----------------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def _admit(self) -> None:
        for i in self._free_slots():
            if not self.queue:
                break
            rid, prompt = self.queue.pop(0)
            P = self.sc.prefill_len
            toks = (prompt[:P] + [0] * max(0, P - len(prompt)))
            true_len = min(len(prompt), P)
            cache1 = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                self._cache1_specs, is_leaf=S.is_spec)
            logits, cache1 = self._prefill(
                self.params, jnp.asarray([toks], jnp.int32), cache1)

            # splice the prefilled slot into the batched cache along each
            # leaf's batch axis (scanned leaves lead with the layer axis)
            def splice(C, c, ax):
                idx = tuple([slice(None)] * ax + [i])
                return C.at[idx].set(jnp.take(c, 0, axis=ax))

            self.cache = jax.tree.map(splice, self.cache, cache1,
                                      self._batch_axes)
            # token 0 must honor the sampling settings too — greedy argmax
            # here ignored temperature/top_k for the first generated token
            self._key, k = jax.random.split(self._key)
            first = int(np.asarray(sampler.sample(
                logits[:, true_len - 1], k,
                temperature=self.sc.temperature, top_k=self.sc.top_k))[0])
            self.slots[i] = _Slot(request_id=rid, length=true_len,
                                  generated=[first], active=True)

    def _tick(self) -> None:
        if not any(s.active for s in self.slots):
            return
        B = self.sc.max_slots
        last = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                last[i, 0] = s.generated[-1]
                pos[i] = s.length
        self._key, k = jax.random.split(self._key)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(last), self.cache, jnp.asarray(pos))
        nxt = sampler.sample(logits, k, temperature=self.sc.temperature,
                             top_k=self.sc.top_k)
        nxt = np.asarray(nxt)
        self._steps += 1
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.length += 1
            tok = int(nxt[i])
            s.generated.append(tok)
            done = (tok == self.sc.eos_id
                    or len(s.generated) >= self.sc.max_new_tokens
                    or s.length + 1 >= self.sc.max_seq)
            if done:
                self.outputs[s.request_id] = list(s.generated)
                self.slots[i] = _Slot()
