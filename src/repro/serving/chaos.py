"""Deterministic fault injection for the serving engine.

Generalizes ``distributed.fault.FailureInjector`` to the serving tick
loop: NaN logits, kernel-path exceptions, slow ticks, and queue floods
fire at *configured ticks/rids* — no randomness — so the ``pytest -m
chaos`` suite can assert exact outcomes (which slot retired ``nan``,
how many decode retries, that co-batched streams stayed bit-exact).

How to inject faults
--------------------
Build a :class:`ChaosConfig`, wrap it in a :class:`ChaosMonkey`, and
install it on an engine BEFORE ``run()``::

    from repro.serving.chaos import (ChaosConfig, ChaosMonkey,
                                     KernelFault, NanFault, SlowTick)

    monkey = ChaosMonkey(ChaosConfig(
        nan_logits=(NanFault(tick=3, rid=1),),   # rid=None poisons all
        kernel_failures=(KernelFault(tick=5, count=2),),
        slow_ticks=(SlowTick(tick=7, seconds=2.0),),
    ))
    monkey.install(engine)          # via Engine.add_decode_wrapper
    engine.run()
    print(monkey.injected)          # log of every fault that fired

The monkey wraps the jitted decode callable OUTSIDE jit (host python on
concrete arrays), so installation cannot retrace — the one-decode-trace
invariant holds under injection, and the wrapper survives circuit-breaker
jit re-establishment (``Engine.add_decode_wrapper`` re-applies it).

* :class:`NanFault` overwrites the decode logit rows of the targeted
  active slots with a non-finite value AFTER the kernel ran — the KV
  cache and every other row are untouched, which is exactly the
  quarantine contract the chaos tests verify (co-batched requests
  bit-exact vs a fault-free run).
* :class:`KernelFault` raises from inside the decode call at a given
  tick, ``count`` times — the engine does not advance the tick on
  failure, so ``count`` expresses "fail the first N attempts" and the
  breaker's retry/fallback path is exercised deterministically
  (``count >= breaker_threshold`` forces a fallback or abort).
* :class:`SlowTick` stalls the decode (``sleep_fn``; inject the fake
  registry clock's ``advance`` for deterministic tests) inside the
  watchdog window so straggler detection fires.
* :func:`flood` is the queue-flood: submit ``n`` copies of a prompt at
  once to exercise ``max_queue`` backpressure rejection.

From the CLI, ``repro.launch.serve`` exposes ``--chaos-nan-ticks`` /
``--chaos-kernel-ticks`` (nightly CI runs the injected-NaN drill and
asserts the ``nan`` outcome + distinct trace markers).
"""
from __future__ import annotations

import dataclasses
import math
import time
import weakref
from typing import Callable, Sequence

from repro.distributed.fault import FailureInjector


@dataclasses.dataclass(frozen=True)
class NanFault:
    """Poison decode logits at ``tick`` for ``rid`` (None = every active
    slot) with ``value`` (any non-finite float)."""
    tick: int
    rid: int | None = None
    value: float = math.nan


@dataclasses.dataclass(frozen=True)
class KernelFault:
    """Raise from the decode path at ``tick``, for the first ``count``
    attempts (the engine retries without advancing the tick)."""
    tick: int
    count: int = 1


@dataclasses.dataclass(frozen=True)
class SlowTick:
    """Stall the decode at ``tick`` by ``seconds`` (watchdog straggler)."""
    tick: int
    seconds: float


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    nan_logits: Sequence[NanFault] = ()
    kernel_failures: Sequence[KernelFault] = ()
    slow_ticks: Sequence[SlowTick] = ()


class ChaosError(RuntimeError):
    """The exception :class:`KernelFault` raises (distinguishable from
    genuine kernel failures in logs/tests)."""


class ChaosMonkey:
    """Installs a :class:`ChaosConfig` onto an engine's decode path.

    ``injected`` logs every fault that actually fired, in order —
    ``{"kind": "nan"|"kernel"|"slow", "tick": ..., ...}`` — so tests can
    assert the schedule was exercised (a chaos test whose faults never
    fire is vacuous).
    """

    def __init__(self, cfg: ChaosConfig, *,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.cfg = cfg
        self.injected: list[dict] = []
        self._sleep = sleep_fn
        self._failer = FailureInjector(
            schedule={f.tick: f.count for f in cfg.kernel_failures},
            exc_factory=lambda t: ChaosError(
                f"chaos: injected kernel failure at tick {t}"))
        self._slow_done: set[int] = set()
        self._engine = None

    def install(self, engine) -> "ChaosMonkey":
        """Attach to ``engine`` via :meth:`Engine.add_decode_wrapper`
        (held weakly; survives breaker jit re-establishment)."""
        self._engine = weakref.ref(engine)
        engine.add_decode_wrapper(self._wrap)
        return self

    # the fn -> fn decode wrapper (composed outside jit)
    def _wrap(self, fn):
        def chaotic_decode(params, tokens, cache, pos_vec):
            eng = self._engine() if self._engine is not None else None
            tick = eng.ticks if eng is not None else -1
            try:
                self._failer.maybe_fail(tick)
            except ChaosError:
                self.injected.append({"kind": "kernel", "tick": tick})
                raise
            for st in self.cfg.slow_ticks:
                if st.tick == tick and tick not in self._slow_done:
                    self._slow_done.add(tick)
                    self.injected.append(
                        {"kind": "slow", "tick": tick,
                         "seconds": st.seconds})
                    self._sleep(st.seconds)
            logits, cache = fn(params, tokens, cache, pos_vec)
            if eng is not None:
                for nf in self.cfg.nan_logits:
                    if nf.tick != tick:
                        continue
                    for i, s in enumerate(eng.slots):
                        if s.active and (nf.rid is None
                                         or s.request_id == nf.rid):
                            logits = logits.at[i].set(nf.value)
                            self.injected.append(
                                {"kind": "nan", "tick": tick,
                                 "rid": s.request_id, "slot": i})
            return logits, cache
        return chaotic_decode


def flood(engine, n: int, prompt: Sequence[int] = (1, 2, 3)) -> list[int]:
    """Queue-flood: submit ``n`` copies of ``prompt`` back-to-back.
    Returns the rids (check ``engine.outcome(rid)`` — with
    ``ServeConfig.max_queue`` set, the surplus is ``rejected``)."""
    return [engine.submit(list(prompt)) for _ in range(n)]
