"""End-to-end driver (the paper's kind: INFERENCE): post-training-quantize
the trained bench LM to fine-grained W4A8 with Integer Scale, then serve
batched requests through the continuous-batching engine.

    PYTHONPATH=src python examples/quantize_then_serve.py [--algo gptq]

Prints per-request generations, engine throughput, and the greedy-token
agreement between the Integer-Scale and Float-Scale deployments (the
paper's free-lunch claim, measured end to end on this machine).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax  # noqa: E402

from benchmarks.common import calib_batches, load_bench_model  # noqa: E402
from repro.core import ptq  # noqa: E402
from repro.core.recipe import QuantRecipe, QuantSpec  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticPipeline  # noqa: E402
from repro.serving.engine import Engine, ServeConfig  # noqa: E402


def build_engine(api, cfg, params, recipe, max_slots=4):
    sc = ServeConfig(max_slots=max_slots, max_seq=128, prefill_len=32,
                     max_new_tokens=24)
    return Engine(api, cfg, params, sc, recipe=recipe)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="rtn",
                    choices=["rtn", "gptq", "awq", "smoothquant"])
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    api, cfg, params, trained = load_bench_model()
    print(f"[serve] model={cfg.name} trained={trained}")
    cal = calib_batches(1)
    pipe = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, batch_size=1))
    prompts = [pipe.batch(200_000 + i)["tokens"][0].tolist()
               for i in range(args.requests)]

    outputs = {}
    for name, mode in (("integer-scale", "integer"), ("float-scale",
                                                      "float")):
        spec = QuantSpec(algo=args.algo, scale_mode=mode)
        recipe = QuantRecipe(rules=(("*", spec),), name=f"{args.algo}-{mode}")
        t0 = time.time()
        qparams = ptq.post_training_quantize(api, cfg, params, recipe, cal)
        t_q = time.time() - t0
        eng = build_engine(api, cfg, qparams, recipe)
        for p in prompts:
            eng.submit(p)
        t0 = time.time()
        outs = eng.run()
        dt = time.time() - t0
        toks = sum(len(v) for v in outs.values())
        print(f"[serve] {name:14s} quantize={t_q:.1f}s "
              f"decode_ticks={eng.ticks} generated={toks} tok "
              f"({toks/dt:.1f} tok/s CPU)")
        outputs[name] = outs

    agree = 0
    total = 0
    for rid in outputs["integer-scale"]:
        a = outputs["integer-scale"][rid]
        b = outputs["float-scale"].get(rid, [])
        n = min(len(a), len(b))
        agree += sum(x == y for x, y in zip(a[:n], b[:n]))
        total += n
    print(f"[serve] IS-vs-FS greedy agreement: {agree}/{total} "
          f"({100*agree/max(total,1):.1f}%) — the free lunch, end to end")
    for rid, toks in sorted(outputs["integer-scale"].items())[:3]:
        print(f"[serve] request {rid}: {toks}")


if __name__ == "__main__":
    main()
