"""Paper Listing 1 / Table 7 in miniature: sweep the Integer Scale
amplifier on one trained weight matrix and print the error trade-off.

    PYTHONPATH=src python examples/amplifier_ablation.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import load_bench_model  # noqa: E402
from repro.core import integer_scale as isc  # noqa: E402
from repro.core import quant  # noqa: E402


def main() -> None:
    _, cfg, params, trained = load_bench_model()
    w = np.asarray(params["blocks"]["s0"]["mlp"]["gate"]["w"][0],
                   np.float32)  # layer-0 gate proj
    qw = quant.quantize_weight(jnp.asarray(w), 4, 128)
    n = int(isc.heuristic_amplifier_exp(qw.scale))
    print(f"weight {w.shape}, trained={trained}")
    print(f"Listing-1 heuristic: {n} bit shifts -> alpha=2^{n}={2**n}")
    print(f"{'alpha':>8s} {'weight MSE(IS vs FS)':>22s} "
          f"{'overflow bound /2^31':>22s}")
    for a in [2 ** n, 128, 512, 1024, 4096, 16384]:
        mse = float(isc.integerization_weight_mse(qw, a))
        isw = isc.integerize(qw, a)
        frac = isc.overflow_bound(isw) / 2**31
        tag = " (heuristic)" if a == 2 ** n else ""
        print(f"{a:8d} {mse:22.3e} {frac:22.4f}{tag}")


if __name__ == "__main__":
    main()
