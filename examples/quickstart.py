"""Quickstart: train the bench LM on the synthetic corpus (CPU).

    PYTHONPATH=src python examples/quickstart.py --steps 250

Produces results/bench_lm_ckpt/ — the trained model every quality
benchmark (Tables 1/3/4/7 reproductions) quantizes and evaluates.
Training is fault-tolerant: rerunning resumes from the latest checkpoint;
`--fail-at-step N` demonstrates the injected-failure restart drill.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.paper_llama import bench_lm  # noqa: E402
from repro.data.pipeline import DataConfig  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402
from repro.training.optimizer import AdamWConfig  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="results/bench_lm_ckpt")
    ap.add_argument("--fail-at-step", type=int, default=None)
    args = ap.parse_args()

    cfg = bench_lm()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          batch_size=args.batch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    _, _, hist = train_loop(
        cfg, data_cfg, opt_cfg, steps=args.steps, ckpt_dir=args.ckpt,
        ckpt_every=50, fail_at_step=args.fail_at_step)
    print(f"[quickstart] loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
