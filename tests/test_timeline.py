"""Timeline export: deterministic Perfetto golden with a fake clock,
engine lifecycle exactly-once coverage, and device-timer attribution."""
import json

import pytest

from repro import obs


class FakeClock:
    """Monotonically increasing stub: each reading advances by ``step``."""

    def __init__(self, start: float = 100.0, step: float = 0.25):
        self.t = start - step
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


class TestTimelineGolden:
    """The exporter is a pure function of the event log; with an injected
    fake clock the whole pipeline (span -> event -> traceEvents) is
    byte-deterministic."""

    def _registry(self) -> obs.Registry:
        reg = obs.Registry(clock=FakeClock(start=100.0, step=0.25))
        # submit at t=100.0 (emit consumes one reading)
        reg.emit({"ev": "submit", "rid": 0, "trace_id": "eng0/r0",
                  "prompt_len": 8})
        # admit span: enter t=100.25, exit t=100.5 -> seconds=0.25; the
        # emit inside __exit__ consumes t=100.75 but ts is the start
        with obs.span(reg, "engine_phase_seconds", phase="prefill",
                      event="admit") as sp:
            sp.fields.update(rid=0, slot=1, prompt_len=8,
                             trace_id="eng0/r0", ttft_s=0.5)
        # decode tick span: enter t=101.0, exit t=101.25
        with obs.span(reg, "engine_phase_seconds", phase="decode",
                      event="tick") as sp:
            sp.fields.update(tick=0, slots_active=1, queue_depth=0,
                             slot_rids=[-1, 0])
        # counters sample at t=101.75 (one reading for emit)
        reg.emit({"ev": "counters", "tick": 0, "moe_executed": 10,
                  "moe_total": 16, "qgemm_calls": 3})
        # retire at t=102.0
        reg.emit({"ev": "retire", "rid": 0, "slot": 1,
                  "trace_id": "eng0/r0", "tokens": 2, "tpot_s": 0.25})
        return reg

    def test_golden_trace_events(self):
        doc = obs.build_trace(self._registry())
        assert doc["displayTimeUnit"] == "ms"
        golden = [
            {"ph": "M", "pid": 1, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
             "args": {"name": "phases"}},
            {"ph": "M", "pid": 2, "name": "process_name",
             "args": {"name": "requests"}},
            # admit event -> engine-phase slice + request-lane slices
            {"ph": "X", "pid": 1, "tid": 0, "name": "prefill",
             "ts": 100.25e6, "dur": 0.25e6,
             "args": {"rid": 0, "slot": 1, "prompt_len": 8}},
            {"ph": "M", "pid": 2, "tid": 1, "name": "thread_name",
             "args": {"name": "slot 1"}},
            {"ph": "X", "pid": 2, "tid": 1, "name": "r0 queued",
             "ts": 100.0e6, "dur": 0.25e6},
            {"ph": "X", "pid": 2, "tid": 1, "name": "r0 prefill",
             "ts": 100.25e6, "dur": 0.25e6,
             "args": {"rid": 0, "prompt_len": 8, "trace_id": "eng0/r0"}},
            {"ph": "i", "s": "t", "pid": 2, "tid": 1, "name": "r0 TTFT",
             "ts": 100.5e6, "args": {"ttft_ms": 500.0}},
            # tick event -> engine-phase slice + per-slot decode slice
            {"ph": "X", "pid": 1, "tid": 0, "name": "decode",
             "ts": 101.0e6, "dur": 0.25e6,
             "args": {"tick": 0, "slots_active": 1, "queue_depth": 0}},
            {"ph": "X", "pid": 2, "tid": 1, "name": "r0 decode",
             "ts": 101.0e6, "dur": 0.25e6, "args": {"tick": 0}},
            # counters event -> two counter tracks
            {"ph": "C", "pid": 1, "name": "moe_m_tiles", "ts": 101.75e6,
             "args": {"executed": 10, "total": 16}},
            {"ph": "C", "pid": 1, "name": "qgemm_calls", "ts": 101.75e6,
             "args": {"calls": 3}},
            # retire event -> instant on the slot lane
            {"ph": "i", "s": "t", "pid": 2, "tid": 1, "name": "r0 retire",
             "ts": 102.0e6,
             "args": {"tokens": 2, "tpot_ms": 250.0,
                      "trace_id": "eng0/r0"}},
        ]
        assert doc["traceEvents"] == golden
        # a second export is byte-identical (JSON level)
        assert json.dumps(obs.build_trace(self._registry())) \
            == json.dumps(doc)

    def test_write_trace_roundtrip(self, tmp_path):
        p = tmp_path / "trace.json"
        n = obs.write_trace(str(p), self._registry())
        doc = json.loads(p.read_text())
        assert len(doc["traceEvents"]) == n > 0

    def test_events_without_ts_skipped(self):
        reg = obs.Registry()
        # hand-built event that predates ts stamping
        reg._events.append({"seq": 1, "ev": "tick", "phase": "decode"})
        assert [e for e in obs.timeline.trace_events(reg.events())
                if e["ph"] != "M"] == []


class TestDeviceTimer:
    def test_warmup_excluded_then_observed(self):
        reg = obs.Registry(clock=FakeClock(start=0.0, step=0.5))
        calls = []

        def fn(x):
            calls.append(x)
            return x  # plain python value: block_until_ready is a no-op

        timed = obs.device_timer(fn, "step_device_seconds", warmup=1,
                                 phase="decode")
        with obs.use_registry(reg):
            assert timed(1) == 1 and timed(2) == 2 and timed(3) == 3
        assert calls == [1, 2, 3] and timed.calls() == 3
        snap = reg.snapshot()
        h = snap["histograms"]["step_device_seconds"]['phase="decode"']
        assert h["count"] == 2  # first (compile) call excluded
        # fake clock: each timed call spans one 0.5s step
        assert h["sum"] == pytest.approx(1.0)
        warm = snap["counters"]["step_device_warmup_total"]
        assert warm == {'phase="decode"': 1.0}

    def test_metric_name_contract(self):
        with pytest.raises(ValueError):
            obs.device_timer(lambda: None, "step_seconds")

    def test_trace_window_noop_when_unset(self):
        with obs.trace_window(None) as d:
            assert d is None
        with obs.trace_window("") as d:
            assert d is None


class TestEngineTimeline:
    """Interpret-free engine run (tiny dense model, reference kernels):
    every admitted request's lifecycle events appear exactly once in the
    exported timeline."""

    @pytest.fixture(scope="class")
    def run(self):
        import jax
        import numpy as np

        from repro.models.config import ModelConfig
        from repro.models.registry import get_model
        from repro.nn import spec as S
        from repro.serving.engine import Engine, ServeConfig

        cfg = ModelConfig(name="t", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=64, dtype="float32",
                          q_chunk=16, kv_chunk=16, remat=False)
        api = get_model(cfg)
        params = S.materialize(api.param_specs(cfg, None),
                               jax.random.PRNGKey(0))
        reg = obs.Registry()
        with obs.use_registry(reg):
            sc = ServeConfig(max_slots=2, max_seq=64, prefill_len=8,
                             max_new_tokens=4)
            eng = Engine(api, cfg, params, sc)
            rng = np.random.default_rng(0)
            rids = [eng.submit(rng.integers(0, 64, size=8).tolist())
                    for _ in range(5)]  # > max_slots: staggered admission
            outs = eng.run()
            eng.close()
        return reg, eng, rids, outs

    def test_lifecycle_exactly_once(self, run):
        reg, eng, rids, outs = run
        assert set(outs) == set(rids)
        te = obs.build_trace(reg)["traceEvents"]
        names = [e["name"] for e in te]
        for rid in rids:
            assert names.count(f"r{rid} queued") == 1
            assert names.count(f"r{rid} prefill") == 1
            assert names.count(f"r{rid} TTFT") == 1
            assert names.count(f"r{rid} retire") == 1
            # a decode slice for every generated token after the first
            assert names.count(f"r{rid} decode") == len(outs[rid]) - 1

    def test_engine_phase_lane_and_counters(self, run):
        reg, eng, _, _ = run
        te = obs.build_trace(reg)["traceEvents"]
        engine_slices = [e["name"] for e in te
                         if e["ph"] == "X" and e["pid"] == 1]
        assert {"admit", "prefill", "decode", "retire"} \
            <= set(engine_slices)
        assert engine_slices.count("decode") == eng.ticks
        counters = [e for e in te if e["ph"] == "C"]
        assert len(counters) == 2 * eng.ticks  # m-tiles + qgemm per tick
        # slices are ordered and non-negative duration
        for e in te:
            if e["ph"] == "X":
                assert e["dur"] >= 0

    def test_trace_ids_and_device_series(self, run):
        reg, eng, rids, _ = run
        evs = reg.events()
        admits = [e for e in evs if e.get("ev") == "admit"]
        assert sorted(e["trace_id"] for e in admits) \
            == sorted(eng.trace_id(r) for r in rids)
        # device attribution: decode device series excludes the compile
        # call, host series counts every tick
        h = reg.snapshot()["histograms"]
        dev = h["engine_phase_device_seconds"]['phase="decode"']
        host = h["engine_phase_seconds"]['phase="decode"']
        assert host["count"] == eng.ticks
        assert dev["count"] == eng.ticks - 1
        assert eng.decode_traces == 1  # timers added zero retraces
