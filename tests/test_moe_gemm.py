"""Grouped (batched-expert) Pallas kernel validation: interpret-mode parity
vs the per-expert oracles/vmapped reference, ragged-capacity behavior, and
the int32 overflow audit on the grouped accumulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integer_scale as isc
from repro.core import packing, qlinear, quant
from repro.core.recipe import QuantSpec
from repro.kernels import ref as KR
from repro.kernels.moe_gemm import (fg_grouped_gemm_float_scale,
                                    fg_grouped_gemm_integer_scale,
                                    grouped_w4a16_gemm)

jax.config.update("jax_platform_name", "cpu")

SHAPES = [  # (E, C, K, N, g)
    (2, 8, 256, 128, 128),    # minimum-capacity decode-like
    (4, 24, 256, 256, 128),   # phi-3.5-MoE smoke expert dims (d=f=256)
    (3, 16, 512, 384, 128),   # ragged N
    (2, 16, 512, 256, 256),   # larger group
]


def _mk_experts(seed, E, K, N, g, w_bits=4, amplifier=1024):
    """Per-expert quantized weights + stacked kernel operands."""
    keys = jax.random.split(jax.random.PRNGKey(seed), E)
    packed, iscale, fscale, alphas, isws = [], [], [], [], []
    for e in range(E):
        # per-expert magnitude spread so heuristic amplifiers differ
        w = jax.random.normal(keys[e], (K, N)) * 0.05 * (4.0 ** (e % 3))
        qw = quant.quantize_weight(w, w_bits, g)
        isw = isc.integerize(qw, amplifier)
        isws.append(isw)
        packed.append(packing.pack_int4(qw.qvalue) if w_bits == 4
                      else qw.qvalue)
        iscale.append(isw.int_scale)
        fscale.append(qw.scale)
        alphas.append(float(isw.alpha))
    return (jnp.stack(packed), jnp.stack(iscale), jnp.stack(fscale),
            alphas, isws)


def _mk_acts(seed, E, C, K):
    x = jax.random.normal(jax.random.PRNGKey(seed), (E, C, K))
    xq, sa = quant.quantize_activation(x.reshape(E * C, K))
    return x, xq.reshape(E, C, K), sa.reshape(E, C, 1)


@pytest.mark.parametrize("E,C,K,N,g", SHAPES)
def test_grouped_is_kernel_bit_exact_vs_vmapped_ref(E, C, K, N, g):
    qv, iscale, _, alphas, _ = _mk_experts(0, E, K, N, g)
    _, xq, sa = _mk_acts(1, E, C, K)
    y_k = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g, alpha=1024.0, interpret=True)
    y_r = jnp.stack([
        KR.fg_gemm_is_ref(xq[e], sa[e], qv[e], iscale[e],
                          group_size=g, alpha=1024.0) for e in range(E)])
    # integer path is bit-exact; epilogue is one f32 multiply per element
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_grouped_is_kernel_per_expert_alpha():
    """Heuristic amplifiers give each expert its OWN alpha; the grouped
    kernel folds 1/alpha_e into sa and must stay bit-exact per expert."""
    E, C, K, N, g = 4, 16, 256, 256, 128
    qv, iscale, _, alphas, _ = _mk_experts(2, E, K, N, g,
                                           amplifier="heuristic+6")
    assert len(set(alphas)) > 1, "want distinct per-expert amplifiers"
    _, xq, sa = _mk_acts(3, E, C, K)
    y_k = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g,
        alpha=jnp.asarray(alphas, jnp.float32), interpret=True)
    y_r = jnp.stack([
        KR.fg_gemm_is_ref(xq[e], sa[e], qv[e], iscale[e],
                          group_size=g, alpha=alphas[e]) for e in range(E)])
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("E,C,K,N,g", SHAPES[:2])
def test_grouped_fs_kernel_vs_vmapped_ref(E, C, K, N, g):
    qv, _, fscale, _, _ = _mk_experts(4, E, K, N, g)
    _, xq, sa = _mk_acts(5, E, C, K)
    y_k = fg_grouped_gemm_float_scale(
        xq, sa, qv, fscale, group_size=g, interpret=True)
    y_r = jnp.stack([
        KR.fg_gemm_fs_ref(xq[e], sa[e], qv[e], fscale[e], group_size=g)
        for e in range(E)])
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-4)


def test_grouped_w8_is_kernel_vs_vmapped_ref():
    E, C, K, N, g = 2, 16, 256, 128, 128
    qv, iscale, _, alphas, _ = _mk_experts(6, E, K, N, g, w_bits=8,
                                           amplifier="heuristic+6")
    _, xq, sa = _mk_acts(7, E, C, K)
    y_k = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g, w_bits=8,
        alpha=jnp.asarray(alphas, jnp.float32), interpret=True)
    y_r = jnp.stack([
        KR.fg_gemm_is_ref(xq[e], sa[e], qv[e], iscale[e], group_size=g,
                          alpha=alphas[e], w_bits=8) for e in range(E)])
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_grouped_w4a16_kernel_vs_vmapped_ref():
    E, C, K, N, g = 3, 16, 256, 256, 128
    qv, _, fscale, _, _ = _mk_experts(8, E, K, N, g)
    x = jax.random.normal(jax.random.PRNGKey(9), (E, C, K)).astype(
        jnp.bfloat16)
    y_k = grouped_w4a16_gemm(x, qv, fscale, group_size=g, interpret=True)
    y_r = jnp.stack([
        KR.w4a16_gemm_ref(x[e], qv[e], fscale[e], group_size=g)
        for e in range(E)])
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-2, atol=2e-2)


def test_grouped_is_kernel_ragged_capacity_padding():
    """Dispatch buffers zero-fill capacity slots past each expert's routed
    token count; padded rows must produce exactly-zero outputs and leave
    the valid rows bit-identical to an unpadded run."""
    E, C, K, N, g = 3, 24, 256, 128, 128
    qv, iscale, _, _, _ = _mk_experts(10, E, K, N, g)
    _, xq, sa = _mk_acts(11, E, C, K)
    counts = [5, 24, 0]  # ragged per-expert occupancy, incl. empty expert
    rows = jnp.arange(C)[None, :, None]
    mask = rows < jnp.asarray(counts)[:, None, None]
    xq_ragged = jnp.where(mask, xq, 0).astype(jnp.int8)
    sa_ragged = jnp.where(mask, sa, 0.0)
    y = fg_grouped_gemm_integer_scale(
        xq_ragged, sa_ragged, qv, iscale, group_size=g, alpha=1024.0,
        interpret=True)
    y_full = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g, alpha=1024.0, interpret=True)
    for e, c in enumerate(counts):
        np.testing.assert_array_equal(np.asarray(y[e, :c]),
                                      np.asarray(y_full[e, :c]))
        np.testing.assert_array_equal(np.asarray(y[e, c:]),
                                      np.zeros((C - c, N), np.float32))


def test_grouped_kernel_block_shape_sweep():
    """BlockSpec tiling (incl. capacity padding to bm) must not change
    results."""
    E, C, K, N, g = 2, 20, 512, 256, 128
    qv, iscale, _, _, _ = _mk_experts(12, E, K, N, g)
    _, xq, sa = _mk_acts(13, E, C, K)
    ref = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g, alpha=1024.0, interpret=True)
    for bm, bn, bk in [(8, 128, 128), (16, 256, 256), (128, 128, 512)]:
        y = fg_grouped_gemm_integer_scale(
            xq, sa, qv, iscale, group_size=g, alpha=1024.0,
            bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref),
                                      err_msg=f"blocks={(bm, bn, bk)}")


def test_grouped_accumulator_overflow_audit():
    """The grouped kernel shares the dense kernel's int32 accumulator; per
    expert, the static worst-case bound and the empirical max |accumulator|
    for a batch of dispatch activations must clear 2^31 with the default
    amplifier at MoE expert shapes."""
    E, C, K, N, g = 4, 16, 256, 128, 128
    _, iscale, _, _, isws = _mk_experts(14, E, K, N, g)
    _, xq, _ = _mk_acts(15, E, C, K)
    for e, isw in enumerate(isws):
        assert not isc.would_overflow(isw), (
            f"expert {e}: static bound {isc.overflow_bound(isw):,} >= 2^31")
        emp = isc.empirical_max_accum(xq[e], isw)
        assert emp < 2 ** 31
        assert emp <= isc.overflow_bound(isw)


def test_grouped_linear_apply_pallas_matches_reference():
    """qlinear.grouped_linear_apply: one fused grouped kernel == vmapped
    reference GEMM on identical pre-quantized operands (per-expert alpha
    and stacked bias included)."""
    E, C, K, N, g = 4, 16, 256, 256, 128
    qv, iscale, _, alphas, _ = _mk_experts(16, E, K, N, g,
                                           amplifier="heuristic+6")
    params = {
        "qvalue": qv,
        "scale": iscale,
        "alpha": jnp.asarray(alphas, jnp.float32),
        "b": jax.random.normal(jax.random.PRNGKey(17), (E, N)) * 0.1,
    }
    spec = QuantSpec(amplifier="heuristic+6")
    x = jax.random.normal(jax.random.PRNGKey(18), (E, C, K))
    y_pal = qlinear.grouped_linear_apply(params, x, spec,
                                         mode="pallas_interpret")
    y_ref = qlinear.grouped_linear_apply(params, x, spec, mode="reference")
    # both branches quantize activations identically up to act_quant
    # rounding ties (see test_kernels.test_act_quant_kernel_vs_oracle)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-2)


def test_expert_linear_apply_routes_to_grouped_kernel():
    """models.moe.expert_linear_apply under pallas_interpret must equal the
    reference route (same stacked params, same dispatch buffer)."""
    from repro.models.moe import expert_linear_apply

    E, C, K, N, g = 4, 16, 256, 256, 128
    qv, iscale, _, _, _ = _mk_experts(19, E, K, N, g)
    params = {"qvalue": qv, "scale": iscale,
              "alpha": jnp.full((E,), 1024.0, jnp.float32)}
    spec = QuantSpec()
    x = jax.random.normal(jax.random.PRNGKey(20), (E, C, K)).astype(
        jnp.bfloat16)
    with qlinear.kernel_mode("pallas_interpret"):
        y_pal = expert_linear_apply(params, x, spec)
    y_ref = expert_linear_apply(params, x, spec)
    np.testing.assert_allclose(
        np.asarray(y_pal, dtype=np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-2)
