"""repro.obs: registry determinism, label isolation, Prometheus golden,
and engine telemetry wired end-to-end (ragged m-tile ground truth)."""
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.kernels.moe_gemm import ragged_tile_stats

jax.config.update("jax_platform_name", "cpu")


class TestHistogram:
    def test_deterministic_bucketing(self):
        reg = obs.Registry()
        h = reg.histogram("lat_seconds", "t", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.01, 0.02, 0.5, 2.0):  # 0.01 is inclusive (le)
            h.observe(v)
        st = h.get()
        assert st["buckets"] == [2, 1, 1, 1]  # last slot = +Inf overflow
        assert st["count"] == 5
        assert st["sum"] == pytest.approx(2.535)
        assert h.cumulative() == {"0.01": 2, "0.1": 3, "1": 4, "+Inf": 5}

    def test_edges_frozen_and_sorted(self):
        reg = obs.Registry()
        h = reg.histogram("h", "", buckets=(1.0, 0.5))
        assert h.buckets == (0.5, 1.0)
        # get-or-create returns the SAME metric; edges cannot be re-declared
        assert reg.histogram("h", "", buckets=(9.0,)) is h
        assert h.buckets == (0.5, 1.0)

    def test_empty_or_duplicate_edges_rejected(self):
        reg = obs.Registry()
        with pytest.raises(ValueError):
            reg.histogram("a", "", buckets=())
        with pytest.raises(ValueError):
            reg.histogram("b", "", buckets=(1.0, 1.0))


class TestQuantiles:
    """Histogram.quantile(): Prometheus histogram_quantile semantics —
    linear interpolation inside the bucket holding the q*count-th
    observation, lower bound 0, overflow clamped to the last edge."""

    def _hist(self):
        reg = obs.Registry()
        h = reg.histogram("q_seconds", "", buckets=(1.0, 2.0, 4.0))
        return h

    def test_in_bucket_interpolation(self):
        h = self._hist()
        for v in (0.5, 1.5, 1.5, 3.0):
            h.observe(v)
        # p50: target=2.0 obs; bucket (1,2] holds obs 2..3 -> interpolate
        # 1 + (2-1) * (2-1)/2 = 1.5
        assert h.quantile(0.5) == pytest.approx(1.5)
        # p25 lands in the first bucket: interpolation starts from the
        # lower bound 0 (not -inf); target == full bucket -> the edge
        assert h.quantile(0.25) == pytest.approx(1.0)
        assert h.quantile(0.0) == pytest.approx(0.0)
        assert h.quantile(1.0) == pytest.approx(4.0)

    def test_overflow_clamps_to_last_edge(self):
        h = self._hist()
        h.observe(100.0)
        h.observe(200.0)
        # both observations are beyond the last finite edge: the best the
        # fixed buckets can say is ">= 4.0" -> clamp, never extrapolate
        assert h.quantile(0.5) == pytest.approx(4.0)
        assert h.quantile(0.99) == pytest.approx(4.0)

    def test_empty_is_nan_and_bounds_checked(self):
        import math
        h = self._hist()
        assert math.isnan(h.quantile(0.5))
        h.observe(0.5)
        with pytest.raises(ValueError):
            h.quantile(-0.1)
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_labeled_series_independent(self):
        reg = obs.Registry()
        h = reg.histogram("q_seconds", "", ("phase",),
                          buckets=(1.0, 2.0))
        h.observe(0.5, phase="prefill")
        h.observe(1.5, phase="decode")
        assert h.quantile(0.5, phase="prefill") < 1.0
        assert h.quantile(0.5, phase="decode") > 1.0
        qs = h.quantiles(phase="decode")
        assert set(qs) == {"p50", "p95", "p99"}

    def test_snapshot_carries_quantiles(self):
        reg = obs.Registry()
        h = reg.histogram("lat_seconds", "", buckets=(1.0, 2.0))
        for v in (0.5, 1.5, 3.0):
            h.observe(v)
        st = reg.snapshot()["histograms"]["lat_seconds"][""]
        assert st["quantiles"]["p50"] == pytest.approx(1.5)
        assert st["quantiles"]["p99"] == pytest.approx(2.0)


class TestLabels:
    def test_series_isolation(self):
        reg = obs.Registry()
        c = reg.counter("calls_total", "", ("scheme", "kind"))
        c.inc(scheme="is", kind="dense")
        c.inc(3, scheme="is", kind="grouped")
        c.inc(scheme="fs", kind="dense")
        assert c.get(scheme="is", kind="dense") == 1
        assert c.get(scheme="is", kind="grouped") == 3
        assert c.get(scheme="fs", kind="grouped") == 0
        assert c.total() == 5

    def test_label_mismatch_rejected(self):
        reg = obs.Registry()
        c = reg.counter("c", "", ("a",))
        with pytest.raises(ValueError):
            c.inc(b="x")
        with pytest.raises(ValueError):
            c.inc()  # missing declared label

    def test_redeclaration_shape_checked(self):
        reg = obs.Registry()
        reg.counter("m", "", ("a",))
        with pytest.raises(ValueError):
            reg.gauge("m", "", ("a",))
        with pytest.raises(ValueError):
            reg.counter("m", "", ("a", "b"))

    def test_counter_monotone(self):
        reg = obs.Registry()
        with pytest.raises(ValueError):
            reg.counter("c", "").inc(-1)


class TestPrometheusGolden:
    def test_golden_snapshot(self):
        reg = obs.Registry()
        reg.counter("b_total", "calls", ("scheme",)).inc(2, scheme="is")
        reg.counter("b_total", "calls", ("scheme",)).inc(scheme="fs")
        reg.gauge("a_depth", "queue").set(3)
        h = reg.histogram("c_seconds", "lat", ("phase",),
                          buckets=(0.1, 1.0))
        h.observe(0.05, phase="decode")
        h.observe(0.5, phase="decode")
        golden = "\n".join([
            '# HELP a_depth queue',
            '# TYPE a_depth gauge',
            'a_depth 3',
            '# HELP b_total calls',
            '# TYPE b_total counter',
            'b_total{scheme="fs"} 1',
            'b_total{scheme="is"} 2',
            '# HELP c_seconds lat',
            '# TYPE c_seconds histogram',
            'c_seconds_bucket{phase="decode",le="0.1"} 1',
            'c_seconds_bucket{phase="decode",le="1"} 2',
            'c_seconds_bucket{phase="decode",le="+Inf"} 2',
            'c_seconds_sum{phase="decode"} 0.55',
            'c_seconds_count{phase="decode"} 2',
        ]) + "\n"
        assert reg.prometheus_text() == golden
        # deterministic: a second render is byte-identical
        assert reg.prometheus_text() == golden


class TestPrometheusConformance:
    """Exposition-format conformance locked with a golden file:
    ascending ``le`` ordering, an explicit ``+Inf`` bucket line,
    ``_sum``/``_count`` emission, and label-value escaping of
    backslash, double-quote, and newline."""

    def test_conformance_golden_file(self):
        import pathlib

        reg = obs.Registry()
        c = reg.counter("req_total", "requests served",
                        ("route", "status"))
        c.inc(3, route="decode", status="ok")
        c.inc(route='we"ird\\path\nx', status="err")
        reg.gauge("queue_depth", "pending requests\nsecond line").set(2)
        h = reg.histogram("lat_seconds", "phase latency", ("phase",),
                          buckets=(0.1, 1.0, 10.0))
        h.observe(0.0625, phase="decode")
        h.observe(0.5, phase="decode")
        h.observe(99.0, phase="decode")
        h.observe(0.25, phase="prefill")
        golden = (pathlib.Path(__file__).parent / "golden"
                  / "prometheus_conformance.txt").read_text()
        assert reg.prometheus_text() == golden

    def test_escaping_unit(self):
        reg = obs.Registry()
        reg.counter("c_total", "", ("p",)).inc(p='a\\b"c\nd')
        line = reg.prometheus_text().splitlines()[-1]
        assert line == 'c_total{p="a\\\\b\\"c\\nd"} 1'


class TestRegistryStackAndEvents:
    def test_use_registry_isolates(self):
        inner = obs.Registry()
        obs.current_registry().counter("x_total", "")
        with obs.use_registry(inner):
            assert obs.current_registry() is inner
            inner2 = obs.Registry()
            with obs.use_registry(inner2):
                assert obs.current_registry() is inner2
            assert obs.current_registry() is inner
        assert obs.current_registry() is not inner

    def test_events_jsonl_roundtrip(self, tmp_path):
        reg = obs.Registry()
        reg.emit({"ev": "tick", "n": 1})
        reg.emit({"ev": "retire", "rid": 7})
        reg.counter("t_total", "").inc()
        p = tmp_path / "m.jsonl"
        n = reg.write_events_jsonl(str(p))
        lines = [json.loads(ln) for ln in p.read_text().splitlines()]
        assert n == 3 and len(lines) == 3
        assert [ln.get("ev") for ln in lines[:2]] == ["tick", "retire"]
        assert lines[0]["seq"] == 1 and lines[1]["seq"] == 2
        snap = lines[-1]["snapshot"]
        assert snap["counters"]["t_total"] == {"": 1.0}
        assert snap["events_total"] == 2

    def test_span_records_histogram_and_event(self):
        reg = obs.Registry()
        with obs.span(reg, "p_seconds", event="tick", phase="decode") as sp:
            sp.fields["tick"] = 0
        assert sp.seconds >= 0
        st = reg.histogram("p_seconds", "", ("phase",)).get(phase="decode")
        assert st["count"] == 1
        ev = reg.events()[-1]
        assert ev["ev"] == "tick" and ev["phase"] == "decode"
        assert ev["tick"] == 0 and "seconds" in ev


class TestEngineTelemetry:
    """Engine run (pallas_interpret, Mixtral smoke shape): ragged
    executed-m-tile counters must match ``ragged_tile_stats`` ground truth
    (the same accounting tests/test_moe_ragged.py validates against the
    kernel), and instrumentation must add zero retraces."""

    @pytest.fixture(scope="class")
    def run(self):
        from repro.core import ptq
        from repro.core.recipe import DEFAULT_RECIPE
        from repro.models import moe
        from repro.models.registry import get_arch, get_model
        from repro.nn import spec as S
        from repro.serving.engine import Engine, ServeConfig

        cfg = get_arch("mixtral-8x7b", smoke=True)
        api = get_model(cfg)
        params = S.materialize(api.param_specs(cfg, None),
                               jax.random.PRNGKey(0))
        reg = obs.Registry()
        with obs.use_registry(reg):
            qp = ptq.post_training_quantize(api, cfg, params,
                                            DEFAULT_RECIPE, None)
            sc = ServeConfig(max_slots=2, max_seq=32, prefill_len=8,
                             max_new_tokens=3,
                             kernel_mode="pallas_interpret")
            trace = moe.start_routing_trace()
            eng = Engine(api, cfg, qp, sc, recipe=DEFAULT_RECIPE)
            rng = np.random.default_rng(0)
            for _ in range(3):
                eng.submit(rng.integers(1, cfg.vocab_size, 6).tolist())
            outs = eng.run()
            moe.stop_routing_trace(trace)
            eng.close()
        return reg, eng, trace, outs

    def test_ragged_m_tiles_match_ground_truth(self, run):
        reg, eng, trace, _ = run
        assert trace, "routing trace captured no records"
        expected_exec = expected_total = 0
        for rec in trace:
            counts, C = rec["counts"], rec["capacity"]
            for g in range(counts.shape[0]):
                st = ragged_tile_stats([int(v) for v in counts[g]], C)
                expected_total += st["dense_m_tiles"]
                expected_exec += (st["ragged_m_tiles"]
                                  if counts.shape[0] == 1
                                  else st["dense_m_tiles"])
        tiles = reg.snapshot()["counters"]["engine_moe_m_tiles_total"]
        assert tiles['kind="executed"'] == expected_exec
        assert tiles['kind="total"'] == expected_total
        assert 0 < expected_exec < expected_total  # skipping really engaged

    def test_no_retrace_and_tick_accounting(self, run):
        reg, eng, _, outs = run
        assert eng.decode_traces == 1
        assert eng.prefill_traces == 1
        snap = reg.snapshot()
        c = snap["counters"]
        assert c["engine_traces_total"] == {'fn="decode"': 1.0,
                                            'fn="prefill"': 1.0}
        assert c["engine_ticks_total"][""] == eng.ticks
        assert c["engine_requests_total"] == {'event="submitted"': 3.0,
                                              'event="admitted"': 3.0,
                                              'event="retired"': 3.0}
        # conservation: every submitted rid reached exactly one outcome
        out = c["engine_request_outcomes_total"]
        assert out['outcome="ok"'] == 3.0
        assert sum(out.values()) == c["engine_requests_total"][
            'event="submitted"']
        assert c["engine_tokens_total"][""] == sum(
            len(v) - 1 for v in outs.values())  # first token from prefill
        # per-request latency histograms: one observation per request
        h = snap["histograms"]
        assert h["engine_ttft_seconds"][""]["count"] == 3
        assert h["engine_tpot_seconds"][""]["count"] == 3
        assert h["engine_phase_seconds"]['phase="decode"']["count"] \
            == eng.ticks
        # headline health keys exist in the snapshot (explicit zeros ok)
        assert c["alpha_cap_events_total"] == {"": 0.0}
        assert any('scheme="w4a8-is"' in k
                   for k in c["qgemm_calls_total"])

    def test_events_carry_decode_latency_and_rids(self, run):
        reg, _, _, outs = run
        evs = reg.events()
        ticks = [e for e in evs if e.get("ev") == "tick"]
        assert ticks and all("seconds" in e and "slots_active" in e
                             for e in ticks)
        retired = {e["rid"] for e in evs if e.get("ev") == "retire"}
        assert retired == set(outs)

    def test_timeline_lifecycle_exactly_once(self, run):
        # interpret-mode quantized run: every admitted request's
        # lifecycle events appear exactly once in the exported timeline
        reg, eng, _, outs = run
        names = [e["name"] for e in obs.build_trace(reg)["traceEvents"]]
        for rid in outs:
            for stage in ("queued", "prefill", "TTFT", "retire"):
                assert names.count(f"r{rid} {stage}") == 1, \
                    f"r{rid} {stage} not exactly-once"
            assert names.count(f"r{rid} decode") == len(outs[rid]) - 1
        assert names.count("prefill") == len(outs)  # engine-phase lane
