"""Pallas flash-attention kernel vs the pure-JAX reference (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention as flash_ref
from repro.kernels.flash_attention import flash_attention_tpu

jax.config.update("jax_platform_name", "cpu")

CASES = [  # (B, Sq, Sk, Hq, Hkv, D, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),     # GQA causal
    (1, 128, 128, 2, 1, 64, True, None),   # MQA
    (2, 64, 64, 4, 4, 32, False, None),    # bidirectional (encoder)
    (1, 96, 96, 2, 2, 32, True, 32),       # sliding window
    (2, 40, 72, 2, 2, 32, False, None),    # ragged cross-attn shapes
]


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window", CASES)
def test_flash_kernel_vs_reference(B, Sq, Sk, Hq, Hkv, D, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32)
    ref = flash_ref(q, k, v, causal=causal, window=window,
                    q_chunk=32, kv_chunk=32)
    out = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_block_sweep():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    ref = flash_ref(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    for bq, bk in [(8, 16), (16, 64), (64, 32), (64, 64)]:
        out = flash_attention_tpu(q, k, v, causal=True, bq=bq, bk=bk,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4), (bq, bk)


def test_flash_kernel_bf16():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(jnp.bfloat16)
    ref = flash_ref(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    out = flash_attention_tpu(q, k, v, causal=True, bq=32, bk=32,
                              interpret=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2)


def test_model_with_pallas_attention_matches_jax_path():
    """attention_impl="pallas_interpret" end to end through a model."""
    import dataclasses

    from repro.models.registry import get_arch, get_model
    from repro.nn import spec as S

    cfg = get_arch("llama3.2-3b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    l_jax, _, _ = api.apply(params, cfg, toks, mode="train")
    cfg_p = dataclasses.replace(cfg, attention_impl="pallas_interpret")
    l_pal, _, _ = api.apply(params, cfg_p, toks, mode="train")
    rel = float(jnp.linalg.norm(l_pal - l_jax) / jnp.linalg.norm(l_jax))
    assert rel < 0.02, rel
