"""Core quantization invariants — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dev dep shim

from repro.core import integer_scale as isc
from repro.core import packing, quant
from repro.core.recipe import QuantSpec
from repro.core import qlinear

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Round-trip and bound properties
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    k=st.sampled_from([128, 256, 384]),
    n=st.sampled_from([8, 64, 96]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
    scale_mag=st.floats(1e-3, 10.0),
)
def test_weight_quant_error_bound(k, n, bits, seed, scale_mag):
    """|w - dequant(quant(w))| <= scale/2 elementwise (RTN property)."""
    w = np.random.default_rng(seed).normal(size=(k, n)) * scale_mag
    qw = quant.quantize_weight(jnp.asarray(w, jnp.float32), bits, 128)
    deq = np.asarray(qw.dequant())
    G = k // 128
    s = np.asarray(qw.scale).reshape(G, 1, n)
    err = np.abs(w.reshape(G, 128, n) - deq.reshape(G, 128, n))
    assert (err <= s / 2 + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(k=st.sampled_from([128, 256, 512]), n=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**16))
def test_pack_unpack_roundtrip(k, n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-7, 8, size=(k, n)).astype(np.int8)
    packed = packing.pack_int4(jnp.asarray(q))
    assert packed.shape == (k // 2, n)
    out = np.asarray(packing.unpack_int4(packed))
    assert (out == q).all()


@settings(max_examples=30, deadline=None)
@given(smin=st.floats(1e-8, 0.999), spread=st.floats(1.0, 100.0))
def test_heuristic_amplifier_listing1(smin, spread):
    """Paper Listing 1 contract: min(scale) * alpha >= 1, alpha = 2^n
    minimal."""
    scales = jnp.asarray([smin, smin * spread], jnp.float32)
    alpha = float(isc.heuristic_amplifier(scales))
    assert alpha >= 1 and (int(alpha) & (int(alpha) - 1)) == 0
    assert smin * alpha >= 1.0 - 1e-4
    if alpha > 1:
        assert smin * (alpha / 2) < 1.0 + 1e-4


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), m=st.sampled_from([1, 7, 32]))
def test_is_equals_fs_when_scales_representable(seed, m):
    """If every group scale is exactly j/alpha, integer and float scale
    GEMMs agree to float rounding."""
    rng = np.random.default_rng(seed)
    K, N, g, alpha = 256, 64, 128, 1024
    codes = rng.integers(-7, 8, size=(K, N)).astype(np.int8)
    scale = (rng.integers(1, 200, size=(K // g, N)) / alpha).astype(
        np.float32)
    qw = quant.QWeight(jnp.asarray(codes), jnp.asarray(scale), 4, g)
    isw = isc.integerize(qw, alpha)
    xq = jnp.asarray(rng.integers(-127, 128, size=(m, K)), jnp.int8)
    sa = jnp.asarray(rng.uniform(0.001, 0.1, size=(m, 1)), jnp.float32)
    y_fs = quant.fg_gemm_float_scale(xq, sa, qw)
    y_is = isc.fg_gemm_integer_scale(xq, sa, isw)
    np.testing.assert_allclose(np.asarray(y_is), np.asarray(y_fs),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_safe_fallback_matches_fast_path(seed):
    """§B.4 de-amplified GEMM == fast path when no overflow occurs."""
    rng = np.random.default_rng(seed)
    K, N, m = 256, 32, 8
    w = rng.normal(size=(K, N)).astype(np.float32) * 0.05
    qw = quant.quantize_weight(jnp.asarray(w), 4, 128)
    isw = isc.integerize(qw, 1024)
    x = rng.normal(size=(m, K)).astype(np.float32)
    xq, sa = quant.quantize_activation(jnp.asarray(x))
    y_fast = isc.fg_gemm_integer_scale(xq, sa, isw)
    y_safe = isc.fg_gemm_integer_scale_safe(xq, sa, isw)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_safe),
                               rtol=1e-5, atol=1e-5)


def test_overflow_bound_is_sound():
    """Static bound >= any empirical accumulation (adversarial input)."""
    rng = np.random.default_rng(0)
    K, N = 256, 16
    w = rng.normal(size=(K, N)).astype(np.float32)
    qw = quant.quantize_weight(jnp.asarray(w), 4, 128)
    isw = isc.integerize(qw, 1024)
    xq = jnp.full((4, K), 127, jnp.int8)  # worst-case activation
    emp = int(isc.empirical_max_accum(xq, isw))
    assert emp <= isc.overflow_bound(isw)
    assert isc.overflow_bound(isw) < 2**31  # sane layer never overflows


def test_integerize_rejects_bad_amplifier():
    w = jnp.ones((128, 8))
    qw = quant.quantize_weight(w, 4, 128)
    with pytest.raises(ValueError):
        isc.integerize(qw, 1000)  # not a power of two
    with pytest.raises(ValueError):
        isc.integerize(quant.quantize_weight(w, 4, -1), 1024)  # coarse


def test_amplifier_exp_clamp_unified_at_2_30():
    """Every clamp on the amplifier path uses MAX_AMPLIFIER_EXP = 30
    (heuristic_amplifier_exp used to clip at 31, which heuristic_amplifier
    and integerize then re-clipped to 30 — a silent disagreement)."""
    assert isc.MAX_AMPLIFIER_EXP == 30
    tiny = jnp.asarray([1e-30, 1e-30], jnp.float32)
    exp = int(isc.heuristic_amplifier_exp(tiny))
    assert exp == isc.MAX_AMPLIFIER_EXP
    # the int32 left-shift stays positive and equals 2^exp exactly
    alpha = int(isc.heuristic_amplifier(tiny))
    assert alpha == 2**isc.MAX_AMPLIFIER_EXP > 0

    # heuristic string path: margin bits cannot push past the bound
    rng = np.random.default_rng(0)
    codes = rng.integers(-7, 8, size=(128, 8)).astype(np.int8)
    qw = quant.QWeight(jnp.asarray(codes), jnp.full((1, 8), 1e-30), 4, 128)
    isw = isc.integerize(qw, "heuristic+6")
    assert isw.alpha == 2**isc.MAX_AMPLIFIER_EXP

    # explicit alpha = 2^30 is the edge of legality; 2^31 is rejected
    qw2 = quant.quantize_weight(jnp.ones((128, 8)) * 1e-6, 4, 128)
    isw2 = isc.integerize(qw2, 2**30)
    assert isw2.alpha == 2**30
    assert int(jnp.min(isw2.int_scale)) >= 1
    assert int(jnp.max(isw2.int_scale)) <= 2**31 - 1
    with pytest.raises(ValueError):
        isc.integerize(qw2, 2**31)


# ---------------------------------------------------------------------------
# qlinear end-to-end schemes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    QuantSpec(),  # W4A8-IS (paper default)
    QuantSpec(scale_mode="float"),
    QuantSpec(a_bits=16),
    QuantSpec(w_bits=8, amplifier="heuristic+6"),
    QuantSpec(group_size=-1),
    QuantSpec(a_bits=4),
    QuantSpec(amplifier="heuristic"),
])
def test_qlinear_schemes_close_to_fp(spec):
    key = jax.random.PRNGKey(0)
    K, N, M = 512, 256, 16
    w = jax.random.normal(key, (K, N)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    params = qlinear.quantize_linear(w, spec)
    y = qlinear.linear_apply(params, x.astype(jnp.bfloat16), spec)
    ref = x @ w
    rel = float(jnp.linalg.norm(y.astype(jnp.float32) - ref)
                / jnp.linalg.norm(ref))
    assert rel < (0.35 if spec.a_bits == 4 else 0.25), (spec.name, rel)


def test_qlinear_specs_match_quantize_output():
    """param_specs shapes/dtypes == quantize_linear output (dry-run and
    real params must agree)."""
    spec = QuantSpec()
    K, N = 512, 256
    specs = qlinear.linear_specs(K, N, spec, ("embed", "mlp"))
    params = qlinear.quantize_linear(jnp.ones((K, N)) * 0.01, spec)
    assert set(specs) == set(params)
    for k in specs:
        assert specs[k].shape == params[k].shape, k
        assert jnp.dtype(specs[k].dtype) == params[k].dtype, k
