"""Fallback shims for the OPTIONAL ``hypothesis`` dev dependency.

``hypothesis`` is not part of the runtime environment (see
requirements-dev.txt). Test modules that mix property tests with plain
unit tests import ``given/settings/st`` from here: when hypothesis is
installed the real objects pass straight through; when it is absent the
property tests collect as skipped stubs and the plain tests in the same
module still run — the whole module must NOT be skipped.
"""
from __future__ import annotations

import pytest

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(
                reason="hypothesis not installed (requirements-dev.txt)")
            def skipped():
                pass

            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped

        return deco

    class _AnyStrategy:
        """st.* lookups succeed at collection time; values are only ever
        consumed by the ``given`` stub, which ignores them."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
