"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each assigned arch, run one forward + one train step on
CPU, assert output shapes + no NaNs. Plus prefill/decode == train
consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_arch, get_model, list_archs
from repro.nn import spec as S
from repro.training import optimizer as O
from repro.training.train_step import make_train_step

jax.config.update("jax_platform_name", "cpu")

ARCHS = [
    "granite-34b", "qwen2-72b", "minicpm3-4b", "llama3.2-3b",
    "phi3.5-moe-42b-a6.6b", "deepseek-v2-236b", "llama-3.2-vision-90b",
    "xlstm-1.3b", "recurrentgemma-9b", "whisper-tiny", "llama2-7b",
]


def _inputs(cfg, B=2, Sq=32, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, Sq), 0,
                              cfg.vocab_size)
    mem = None
    if cfg.family == "vlm":
        mem = jax.random.normal(jax.random.PRNGKey(key + 1),
                                (B, cfg.num_image_tokens, cfg.d_model),
                                ) * 0.1
    if cfg.family == "audio":
        mem = jax.random.normal(jax.random.PRNGKey(key + 1),
                                (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return toks, mem


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_archs())


@pytest.mark.slow  # full-arch sweep; quantized family coverage
# stays in the default run via test_system.test_quantized_smoke_*
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg)
    specs = api.param_specs(cfg, None)
    params = S.materialize(specs, jax.random.PRNGKey(0))
    toks, mem = _inputs(cfg)
    logits, _, aux = api.apply(params, cfg, toks, mode="train", memory=mem)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.slow  # full-arch sweep; quantized family coverage
# stays in the default run via test_system.test_quantized_smoke_*
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg)
    specs = api.param_specs(cfg, None)
    params = S.materialize(specs, jax.random.PRNGKey(0))
    opt = S.materialize(O.state_specs(specs), jax.random.PRNGKey(1))
    toks, mem = _inputs(cfg)
    # next-token labels: identity labels saturate softmax at init for
    # tied-embedding archs (gold logit = ||e||^2) -> exp-underflow -> 0 grad
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if mem is not None:
        batch["image_embeds" if cfg.family == "vlm" else "frames"] = mem
    step = jax.jit(make_train_step(api, cfg, O.AdamWConfig(lr=1e-3)))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # optimizer state advanced (bf16 params may not change measurably
    # after ONE small step — the f32 moments must)
    assert int(opt2["step"]) == 1
    mu_norm = sum(float(jnp.sum(jnp.abs(m))) for m in
                  jax.tree.leaves(opt2["mu"]))
    assert mu_norm > 0


@pytest.mark.slow  # full-arch sweep; quantized family coverage
# stays in the default run via test_system.test_quantized_smoke_*
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """decode(prefill(prompt)) logits == train-mode logits, per arch."""
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    B, Sq = 2, 16
    toks, mem = _inputs(cfg, B, Sq)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        api.cache_specs(cfg, B, 48), is_leaf=S.is_spec)
    logits_p, cache, _ = api.apply(params, cfg, toks, mode="prefill",
                                   cache=cache, pos=0, memory=mem)
    nt = jnp.argmax(logits_p[:, -1:], -1)
    logits_d, cache, _ = api.apply(params, cfg, nt, mode="decode",
                                   cache=cache, pos=Sq)
    toks2 = jnp.concatenate([toks, nt], 1)
    logits_full, _, _ = api.apply(params, cfg, toks2, mode="train",
                                  memory=mem)
    err = float(jnp.max(jnp.abs(logits_d[:, 0] - logits_full[:, Sq])))
    tol = 0.15 if cfg.family in ("moe",) else 0.05  # moe: capacity drops
    assert err < tol, err
    assert not bool(jnp.isnan(logits_d).any())


def test_int8_kv_cache_decode():
    """Beyond-paper int8 KV: decode stays close to bf16-KV decode."""
    import dataclasses

    cfg = get_arch("llama3.2-3b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    toks, _ = _inputs(cfg, 2, 16)

    def decode_logits(c):
        a = get_model(c)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             a.cache_specs(c, 2, 48), is_leaf=S.is_spec)
        lp, cache, _ = a.apply(params, c, toks, mode="prefill",
                               cache=cache, pos=0)
        nt = jnp.argmax(lp[:, -1:], -1)
        ld, _, _ = a.apply(params, c, nt, mode="decode", cache=cache,
                           pos=16)
        return ld

    l_bf16 = decode_logits(cfg)
    l_int8 = decode_logits(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    rel = float(jnp.linalg.norm(l_int8 - l_bf16)
                / jnp.linalg.norm(l_bf16))
    assert rel < 0.05, rel
