"""Substrate tests: data determinism, optimizer, checkpointing (atomic/
async/retention/elastic), sharding rules, fault hooks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed.fault import Heartbeat
from repro.distributed import sharding as shard
from repro.nn import spec as S
from repro.training import optimizer as O

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=64, seq_len=32, batch_size=8, num_shards=2)
    p1, p2 = SyntheticPipeline(cfg), SyntheticPipeline(cfg)
    b1, b2 = p1.batch(step=7, shard=1), p2.batch(step=7, shard=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different shards / steps differ
    assert not np.array_equal(p1.batch(7, 0)["tokens"], b1["tokens"])
    assert not np.array_equal(p1.batch(8, 1)["tokens"], b1["tokens"])
    # labels are next-token of tokens
    g = p1.global_batch(3)
    assert g["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(g["tokens"][:, 1:], g["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    specs = {"w": S.w((4,), (None,), init="ones")}
    params = S.materialize(specs, jax.random.PRNGKey(0))
    opt = S.materialize(O.state_specs(specs), jax.random.PRNGKey(1))
    cfg = O.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0)
    target = jnp.asarray([1., -2., 3., 0.5])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, m = O.apply_updates(params, g, opt, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    assert float(O.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t, meta={"loss": 1.5})
    out, meta = mgr.restore(10, jax.tree.map(jnp.zeros_like, t))
    assert meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # no tmp files left behind
    assert not [f for f in os.listdir(tmp_path) if f.startswith("tmp.")]


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree())
    mgr.wait()
    assert mgr.steps() == [3, 4]  # retention keeps last 2


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore the same checkpoint under a different sharding (the
    node-failure / cluster-resize path)."""
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    mgr.save(1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.sharding.NamedSharding(mesh, P("data"))}
    out, _ = mgr.restore(1, jax.tree.map(jnp.zeros_like, t), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))
    assert out["w"].sharding.spec == P("data")


def test_restart_drill(tmp_path):
    """Train -> injected failure -> restart-from-checkpoint resumes and
    reaches the same final state as an uninterrupted run."""
    from repro.launch.train import train_loop
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      dtype="float32", q_chunk=16, kv_chunk=16, remat=False)
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=4)
    oc = O.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=6)
    logs = []

    # uninterrupted reference
    p_ref, _, _ = train_loop(cfg, dc, oc, steps=6, ckpt_dir=None,
                             log_fn=logs.append)

    ck = str(tmp_path / "drill")
    with pytest.raises(RuntimeError, match="injected"):
        train_loop(cfg, dc, oc, steps=6, ckpt_dir=ck, ckpt_every=2,
                   fail_at_step=4, log_fn=logs.append)
    # restart resumes from step 4 checkpoint and finishes
    p_res, _, hist = train_loop(cfg, dc, oc, steps=6, ckpt_dir=ck,
                                ckpt_every=2, log_fn=logs.append)
    assert hist[0]["step"] == 4  # resumed, not restarted
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_heartbeat_straggler_detection():
    hb = Heartbeat()
    hb.cfg.straggler_factor = 2.0
    import time

    for i in range(6):
        hb.start()
        time.sleep(0.01)
        hb.stop(i)
    hb.start()
    time.sleep(0.15)
    hb.stop(99)
    assert 99 in hb.straggler_steps


def test_heartbeat_stop_without_start_raises():
    """Regression: stop() without start() used to record a ~0s sample
    (``self._t0 or time.monotonic()``) that dragged the straggler median
    toward zero — it must refuse instead."""
    hb = Heartbeat()
    with pytest.raises(RuntimeError, match="without a matching start"):
        hb.stop(0)
    assert hb.times == []  # nothing recorded
    # a matched pair still works, and stop() re-arms the guard
    hb.start()
    hb.stop(1)
    assert len(hb.times) == 1
    with pytest.raises(RuntimeError, match="without a matching start"):
        hb.stop(2)
    assert len(hb.times) == 1


def test_heartbeat_injectable_clock():
    """The serving watchdog drives Heartbeat off the registry clock."""
    t = [0.0]
    hb = Heartbeat(clock=lambda: t[0])
    hb.start()
    t[0] = 2.5
    assert hb.stop(0) == 2.5


def test_failure_injector_schedule():
    """Generalized form: per-step failure counts + custom exceptions
    (the serving chaos harness's substrate)."""
    from repro.distributed.fault import FailureInjector

    inj = FailureInjector(schedule={3: 2},
                          exc_factory=lambda s: ValueError(f"boom {s}"))
    inj.maybe_fail(0)
    with pytest.raises(ValueError, match="boom 3"):
        inj.maybe_fail(3)
    with pytest.raises(ValueError, match="boom 3"):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # count exhausted
    assert inj.fired_at == [3, 3]
    # legacy single-shot form unchanged
    legacy = FailureInjector(fail_at_step=1)
    with pytest.raises(RuntimeError, match="injected node failure"):
        legacy.maybe_fail(1)
    legacy.maybe_fail(1)
    assert legacy.fired


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_logical_to_pspec_divisibility_drop():
    sizes = {"data": 16, "model": 16}
    # kv=1 head can't shard 16 ways -> replicated on that dim
    p = S.logical_to_pspec(("cache_batch", "cache_seq", "heads_kv", None),
                           shard.serve_rules(False), sizes,
                           (128, 32768, 1, 128))
    assert p == P("data", "model")
    # divisible case shards
    p2 = S.logical_to_pspec(("embed", "mlp"), shard.train_rules(False),
                            sizes, (6144, 24576))
    assert p2 == P("data", "model")


def test_mesh_axis_used_once():
    sizes = {"data": 4, "model": 4}
    rules = (("a", "model"), ("b", "model"))
    p = S.logical_to_pspec(("a", "b"), rules, sizes, (16, 16))
    assert p == P("model")  # second use dropped


def test_multi_pod_rules_compose_pod_axis():
    sizes = {"pod": 2, "data": 16, "model": 16}
    p = S.logical_to_pspec(("embed", "mlp"), shard.train_rules(True),
                           sizes, (8192, 29568))
    assert p == P(("pod", "data"), "model")
