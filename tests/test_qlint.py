"""repro.analysis: analyzer soundness, lint fixtures, amplifier capping."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.analysis import certify, fixtures, qlint, registry
from repro.analysis.intervals import Interval
from repro.core import integer_scale as isc
from repro.core import qlinear
from repro.core.quant import QWeight
from repro.core.recipe import (DEFAULT_RECIPE, W4A8_FS, W8A8_FG, QuantSpec,
                               certify_recipe)

# ---------------------------------------------------------------------------
# interval domain
# ---------------------------------------------------------------------------


def test_interval_floordiv_exact():
    assert Interval(0, 1).floordiv(Interval.point(2)) == Interval(0.0, 0.0)
    assert Interval(-3, 5).floordiv(Interval.point(2)) == Interval(-2.0, 2.0)
    assert Interval(0, 7).floordiv(Interval(0, 2)) == Interval.top()


def test_interval_nan_corners_widen():
    inf = float("inf")
    # inf - inf corner must widen, not assert
    r = Interval(-inf, inf) - Interval(-inf, inf)
    assert r.lo == -inf and r.hi == inf
    r = Interval(-inf, inf).truediv(Interval(1, inf))
    assert r.lo == -inf and r.hi == inf


# ---------------------------------------------------------------------------
# static bound soundness: dominates the empirical max accumulation
# ---------------------------------------------------------------------------


def _random_case(rng, w_bits, G, gs, alpha):
    K, N, T = G * gs, 8, 16
    qw_max = 2 ** (w_bits - 1) - 1
    codes = rng.integers(-qw_max, qw_max + 1, (K, N)).astype(np.int8)
    scales = rng.uniform(1e-4, 0.05, (G, N)).astype(np.float32)
    isw = isc.integerize(
        QWeight(jnp.asarray(codes), jnp.asarray(scales), w_bits, gs), alpha)
    xq = rng.integers(-127, 128, (T, K)).astype(np.int8)
    return xq, isw


def _assert_dominates(w_bits, G, gs, alpha_exp, seed):
    rng = np.random.default_rng(seed)
    xq, isw = _random_case(rng, w_bits, G, gs, 2 ** alpha_exp)
    bound = certify.static_accum_bound(
        np.asarray(isw.int_scale), group_size=gs, w_bits=w_bits)
    emp = int(isc.empirical_max_accum(xq, isw))
    assert bound >= emp, (w_bits, G, gs, alpha_exp, bound, emp)


@settings(max_examples=25, deadline=None)
@given(w_bits=st.sampled_from([4, 8]), G=st.integers(1, 4),
       gs=st.sampled_from([64, 128]), alpha_exp=st.integers(4, 14),
       seed=st.integers(0, 2**31 - 1))
def test_static_bound_dominates_empirical_prop(w_bits, G, gs, alpha_exp,
                                               seed):
    _assert_dominates(w_bits, G, gs, alpha_exp, seed)


@pytest.mark.parametrize("case", range(8))
def test_static_bound_dominates_empirical(case):
    """Seeded sweep (runs even without hypothesis installed)."""
    rng = np.random.default_rng(case)
    _assert_dominates(int(rng.choice([4, 8])), int(rng.integers(1, 5)),
                      int(rng.choice([64, 128])),
                      int(rng.integers(4, 15)), case)


# ---------------------------------------------------------------------------
# fixtures: deliberately broken kernels must be flagged
# ---------------------------------------------------------------------------

_EXPECT = {
    "broken-fp32-dot": "float-accum-on-is-path",
    "broken-no-preferred": "int-dot-preferred-type",
    "broken-narrowing": "narrowing-convert",
    "broken-index-map": "index-map-bounds",
    "broken-divisibility": "blockspec-divisibility",
}


@pytest.mark.parametrize("entry", fixtures.entries(),
                         ids=lambda e: e.name)
def test_broken_fixture_flagged(entry):
    findings, _, _ = qlint.check_entry(entry)
    assert findings, f"{entry.name}: no findings"
    rules = {f.rule for f in findings}
    assert _EXPECT[entry.name] in rules, (entry.name, rules)


def test_qlint_cli_fixtures_exit_nonzero(capsys):
    assert qlint.main(["--fixtures"]) != 0
    capsys.readouterr()


def test_qlint_cli_clean_subset(capsys):
    # w4a4 entry: full Pallas trace + certification, zero findings
    assert qlint.main(["-k", "w4a4"]) == 0
    out = capsys.readouterr().out
    assert "certified" in out


@pytest.mark.slow
@pytest.mark.parametrize("entry", registry.entries(),
                         ids=lambda e: e.name)
def test_registry_kernel_clean(entry):
    findings, cert, _ = qlint.check_entry(entry)
    assert not findings, [str(f) for f in findings]
    if cert is not None:
        assert cert.verdict == "certified", str(cert)


# ---------------------------------------------------------------------------
# finish_quant wiring: statically unsafe amplifiers are capped
# ---------------------------------------------------------------------------


def test_finish_quant_caps_unsafe_amplifier():
    G, gs, N = 4, 128, 8
    codes = jnp.ones((G * gs, N), jnp.int8) * 7
    scales = jnp.full((G, N), 0.01, jnp.float32)
    spec = QuantSpec(amplifier=2**20)
    certify.clear_log()
    out = qlinear.finish_quant(codes, scales, spec)
    cert = certify.log()[-1]
    assert cert.verdict == "capped-alpha"
    # largest safe power of two for these scales: 2^18
    assert int(out["alpha"]) == 2**18 == cert.resolved_alpha
    np.testing.assert_array_equal(
        np.asarray(out["scale"]), np.full((G, N), round(0.01 * 2**18)))
    assert cert.bound < 2**31


def test_finish_quant_default_alpha_certified():
    G, gs, N = 4, 128, 8
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-7, 8, (G * gs, N)), jnp.int8)
    scales = jnp.asarray(rng.uniform(0.005, 0.02, (G, N)), jnp.float32)
    certify.clear_log()
    out = qlinear.finish_quant(codes, scales, QuantSpec())
    cert = certify.log()[-1]
    assert cert.verdict == "certified"
    assert int(out["alpha"]) == 1024


# ---------------------------------------------------------------------------
# spec/recipe-level verdicts (dry-run surface)
# ---------------------------------------------------------------------------


def test_spec_verdicts():
    assert certify.spec_verdict(QuantSpec(), 512) == "certified"
    assert certify.spec_verdict(W4A8_FS, 512) == "n/a"
    assert certify.spec_verdict(W8A8_FG, 512) == "data-dependent"
    assert certify.spec_verdict(None, 512) == "n/a"
    assert certify.spec_verdict(QuantSpec(), 100) == "n/a"  # K % gs


def test_certify_recipe_default():
    v = certify_recipe(DEFAULT_RECIPE, {"d_model": 256, "d_ff": 512})
    assert v == {"*@d_model": "certified", "*@d_ff": "certified"}
