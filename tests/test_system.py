"""End-to-end system tests: train -> PTQ (all algorithms) -> quantized
apply/serve, quantized smoke for every arch family, dry-run machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ptq
from repro.core.recipe import (DEFAULT_RECIPE, LLAMA3_RECIPE, QuantRecipe,
                               QuantSpec)
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models.config import ModelConfig
from repro.models.registry import get_arch, get_model
from repro.nn import spec as S
from repro.training import optimizer as O
from repro.launch.train import train_loop

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def trained_tiny():
    """Train a small LM for a handful of steps (loss must drop)."""
    cfg = ModelConfig(name="sys", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=128,
                      dtype="float32", q_chunk=32, kv_chunk=32, remat=False)
    dc = DataConfig(vocab_size=128, seq_len=64, batch_size=8)
    oc = O.AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=20)
    params, _, hist = train_loop(cfg, dc, oc, steps=15,
                                 log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8, "loss must drop"
    return get_model(cfg), cfg, params, dc


ALGOS = ["rtn", "gptq", "awq", "smoothquant", "omniquant"]


@pytest.mark.parametrize("algo", ALGOS)
def test_train_ptq_eval_all_algorithms(trained_tiny, algo):
    api, cfg, params, dc = trained_tiny
    pipe = SyntheticPipeline(dc)
    cal = [pipe.global_batch(999)]
    toks = jnp.asarray(pipe.global_batch(1000)["tokens"])
    logits_fp, _, _ = api.apply(params, cfg, toks, mode="train")
    spec = QuantSpec(algo=algo)
    recipe = QuantRecipe(rules=(("*", spec),), name=algo)
    qp = ptq.post_training_quantize(api, cfg, params, recipe, cal)
    logits_q, _, _ = api.apply(qp, cfg, toks, recipe=recipe, mode="train")
    rel = float(jnp.linalg.norm(logits_q - logits_fp)
                / jnp.linalg.norm(logits_fp))
    assert rel < 0.15, (algo, rel)
    # greedy predictions mostly agree with fp
    agree = float(jnp.mean((jnp.argmax(logits_q, -1)
                            == jnp.argmax(logits_fp, -1)).astype(
        jnp.float32)))
    assert agree > 0.9, (algo, agree)


def test_integer_vs_float_scale_free_lunch(trained_tiny):
    """The paper's core claim at system level: IS ~ FS outputs."""
    api, cfg, params, dc = trained_tiny
    toks = jnp.asarray(SyntheticPipeline(dc).global_batch(1001)["tokens"])
    outs = {}
    for mode in ("float", "integer"):
        spec = QuantSpec(scale_mode=mode)
        recipe = QuantRecipe(rules=(("*", spec),), name=mode)
        qp = ptq.post_training_quantize(api, cfg, params, recipe, None)
        logits, _, _ = api.apply(qp, cfg, toks, recipe=recipe, mode="train")
        outs[mode] = logits
    rel = float(jnp.linalg.norm(outs["integer"] - outs["float"])
                / jnp.linalg.norm(outs["float"]))
    assert rel < 0.02, rel  # integerization error only


def test_llama3_recipe_structure(trained_tiny):
    """Paper §5.6 recipe: W8A8 down-proj + rotation + W4A8 elsewhere."""
    api, cfg, params, dc = trained_tiny
    qp = ptq.post_training_quantize(api, cfg, params, LLAMA3_RECIPE, None)
    blk = qp["blocks"]["s0"]["mlp"]
    # down-proj quantized at 8 bit: K dim not nibble-halved
    assert blk["down"]["qvalue"].shape[1] == cfg.d_ff
    assert "rot" in blk["down"]
    # gate is w4: packed K/2
    assert blk["gate"]["qvalue"].shape[1] == cfg.d_model // 2
    toks = jnp.asarray(SyntheticPipeline(dc).global_batch(1002)["tokens"])
    logits, _, _ = api.apply(qp, cfg, toks, recipe=LLAMA3_RECIPE,
                             mode="train")
    assert not bool(jnp.isnan(logits).any())


FAMS = ["llama3.2-3b", "phi3.5-moe-42b-a6.6b", "minicpm3-4b", "xlstm-1.3b",
        "recurrentgemma-9b", "whisper-tiny", "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", FAMS)
def test_quantized_smoke_every_family(arch):
    """W4A8-IS quantized forward for every family's smoke config."""
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(1))
    qp = ptq.post_training_quantize(api, cfg, params, DEFAULT_RECIPE, None)
    # structure must match the quantized spec tree (dry-run consistency)
    qspecs = api.param_specs(cfg, DEFAULT_RECIPE)
    s1 = jax.tree.structure(jax.tree.map(lambda x: 0, qp))
    s2 = jax.tree.structure(jax.tree.map(lambda x: 0, qspecs,
                                         is_leaf=S.is_spec))
    assert s1 == s2
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    mem = None
    if cfg.family == "vlm":
        mem = jnp.zeros((2, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        mem = jnp.zeros((2, cfg.encoder_seq, cfg.d_model))
    logits, _, _ = api.apply(qp, cfg, toks, recipe=DEFAULT_RECIPE,
                             mode="train", memory=mem)
    assert not bool(jnp.isnan(logits).any())


def test_dryrun_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
  %ag = bf16[512,128]{1,0} all-gather(%x), replica_groups=[32,16]<=[512]
  %ar = f32[64]{0} all-reduce(%y), replica_groups={{0,1,2,3}}
  %other = f32[8]{0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 512 * 128 * 2
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 64 * 4
    assert out["total_wire_bytes"] > 0


def test_grad_accum_equivalence(trained_tiny):
    """grad_accum=2 must match the single-batch step numerically."""
    from repro.training.train_step import make_train_step

    api, cfg, params, dc = trained_tiny
    opt = S.materialize(O.state_specs(api.param_specs(cfg, None)),
                        jax.random.PRNGKey(5))
    batch = {k: jnp.asarray(v)
             for k, v in SyntheticPipeline(dc).global_batch(77).items()}
    oc = O.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1 = make_train_step(api, cfg, oc, grad_accum=1)
    s2 = make_train_step(api, cfg, oc, grad_accum=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
