"""kernels.ops v2 call convention: BlockConfig, alpha resolution, and the
hard removal of the v1 shims (their one-release window has passed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qlinear
from repro.core.recipe import QuantSpec
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


def _dense_case(seed=0, K=512, N=256, M=8):
    spec = QuantSpec()
    w = jax.random.normal(jax.random.PRNGKey(seed), (K, N)) * 0.03
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K)).astype(
        jnp.float32)
    return spec, qlinear.quantize_linear(w, spec), x


class TestBlockConfig:
    def test_defaults_match_kernel_defaults(self):
        b = ops.BlockConfig()
        assert (b.bm, b.bn, b.bk, b.interpret) == (128, 256, 512, False)

    @pytest.mark.parametrize("kw", [dict(bm=7), dict(bm=0), dict(bn=100),
                                    dict(bk=-128), dict(bn=64)])
    def test_divisibility_validated_at_construction(self, kw):
        with pytest.raises(ValueError):
            ops.BlockConfig(**kw)

    def test_frozen(self):
        with pytest.raises(Exception):
            ops.BlockConfig().bm = 64

    def test_dict_form_removed(self):
        with pytest.raises(TypeError, match="BlockConfig"):
            ops._as_block({"bm": 64, "bn": 128, "bk": 256})

    def test_rejects_non_block(self):
        with pytest.raises(TypeError):
            ops._as_block("128x256")


class TestUnifiedQgemm:
    def test_param_dict_is_primary_signature(self):
        spec, params, x = _dense_case()
        y = ops.qgemm(x, params, spec, block=ops.INTERPRET)
        y_ref = qlinear.linear_apply(params, x, spec, mode="reference")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-2)

    def test_legacy_positional_form_raises(self):
        spec, params, x = _dense_case()
        with pytest.raises(TypeError):
            ops.qgemm(x, params["qvalue"], params["scale"], spec,
                      alpha=params["alpha"], interpret=True)

    def test_from_params_shim_removed(self):
        assert not hasattr(ops, "qgemm_from_params")
        assert not hasattr(ops, "qgemm_grouped_from_params")

    def test_interpret_kwarg_removed(self):
        spec, params, x = _dense_case()
        with pytest.raises(TypeError):
            ops.qgemm(x, params, spec, interpret=True)

    def test_non_dict_params_raises(self):
        spec, params, x = _dense_case()
        with pytest.raises(TypeError, match="param dict"):
            ops.qgemm(x, params["qvalue"], spec)


class TestUnifiedQgemmGrouped:
    def _grouped_case(self, E=2, C=16, K=256, N=256):
        spec = QuantSpec()
        qps = [qlinear.quantize_linear(
            jax.random.normal(jax.random.PRNGKey(10 + e), (K, N)) * 0.03,
            spec) for e in range(E)]
        params = {k: jnp.stack([p[k] for p in qps]) for k in qps[0]}
        x = jax.random.normal(jax.random.PRNGKey(20), (E, C, K)).astype(
            jnp.float32)
        return spec, params, x

    def test_matches_grouped_linear_apply(self):
        spec, params, x = self._grouped_case()
        y = ops.qgemm_grouped(x, params, spec, block=ops.INTERPRET)
        y_ref = qlinear.grouped_linear_apply(x=x, params=params, qspec=spec,
                                             mode="pallas_interpret")
        np.testing.assert_array_equal(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32))

    def test_grouped_legacy_positional_raises(self):
        spec, params, x = self._grouped_case()
        with pytest.raises(TypeError, match="param dict"):
            ops.qgemm_grouped(x, params["qvalue"], spec)


class TestAlphaResolution:
    def test_static_int_amplifier_is_exact_fallback(self):
        assert ops._resolve_alpha(None, QuantSpec(amplifier=2048)) == 2048.0

    def test_stored_alpha_wins(self):
        assert ops._resolve_alpha(512.0, QuantSpec(amplifier=2048)) == 512.0

    @pytest.mark.parametrize("amp", ["heuristic", "heuristic+6"])
    def test_heuristic_amplifier_without_stored_alpha_raises(self, amp):
        with pytest.raises(ValueError, match="per layer"):
            ops._resolve_alpha(None, QuantSpec(amplifier=amp))


class TestKernelModeContext:
    def test_nesting_and_default(self):
        assert qlinear.current_kernel_mode() == "reference"
        with qlinear.kernel_mode("pallas_interpret"):
            assert qlinear.current_kernel_mode() == "pallas_interpret"
            with qlinear.kernel_mode("pallas"):
                assert qlinear.current_kernel_mode() == "pallas"
            assert qlinear.current_kernel_mode() == "pallas_interpret"
        assert qlinear.current_kernel_mode() == "reference"

    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError):
            with qlinear.kernel_mode("cuda"):
                pass

    def test_legacy_setter_removed(self):
        assert not hasattr(qlinear, "set_default_kernel_mode")
        assert not hasattr(qlinear, "default_kernel_mode")
