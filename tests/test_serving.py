"""Serving engine: continuous batching correctness + quantized serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.nn import spec as S
from repro.serving.engine import Engine, ServeConfig

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      dtype="float32", q_chunk=16, kv_chunk=16, remat=False)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    return api, cfg, params


def _reference_generate(api, cfg, params, prompt, n_new):
    """Single-request greedy generation via full re-forward (oracle)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _, _ = api.apply(params, cfg,
                                 jnp.asarray([toks], jnp.int32),
                                 mode="train")
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_reference_generation(tiny):
    api, cfg, params = tiny
    sc = ServeConfig(max_slots=3, max_seq=64, prefill_len=8,
                     max_new_tokens=6)
    eng = Engine(api, cfg, params, sc)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, size=8).tolist() for _ in range(5)]
    rids = [eng.submit(p) for p in prompts]
    outs = eng.run()
    assert set(outs) == set(rids)
    for rid, p in zip(rids, prompts):
        ref = _reference_generate(api, cfg, params, p, 6)
        assert outs[rid] == ref, (rid, outs[rid], ref)


def test_engine_staggered_admission(tiny):
    """More requests than slots: retirement frees slots; all finish with
    per-slot positions staying correct."""
    api, cfg, params = tiny
    sc = ServeConfig(max_slots=2, max_seq=64, prefill_len=8,
                     max_new_tokens=4)
    eng = Engine(api, cfg, params, sc)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, size=5).tolist() for _ in range(5)]
    rids = [eng.submit(p) for p in prompts]
    outs = eng.run()
    assert set(outs) == set(rids)
    for rid, p in zip(rids, prompts):
        ref = _reference_generate(api, cfg, params, p, 4)
        assert outs[rid] == ref


def test_engine_quantized_serving(tiny):
    """W4A8-IS quantized engine runs and mostly agrees with fp greedy."""
    from repro.core import ptq
    from repro.core.recipe import QuantRecipe, QuantSpec

    api, cfg, params = tiny
    recipe = QuantRecipe(rules=(("*", QuantSpec(group_size=64)),),
                         name="w4a8-is")
    qp = ptq.post_training_quantize(api, cfg, params, recipe, None)
    sc = ServeConfig(max_slots=2, max_seq=64, prefill_len=8,
                     max_new_tokens=5)
    eng = Engine(api, cfg, qp, sc, recipe=recipe)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 64, size=8).tolist() for _ in range(3)]
    rids = [eng.submit(p) for p in prompts]
    outs = eng.run()
    assert set(outs) == set(rids)
    for v in outs.values():
        assert len(v) == 5
        assert all(0 <= t < 64 for t in v)
