"""Engine-driven quantized MoE: parity, ragged decode, no-retrace."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ptq
from repro.core.recipe import DEFAULT_RECIPE
from repro.models import moe
from repro.models.registry import get_arch, get_model
from repro.nn import spec as S
from repro.serving.engine import Engine, ServeConfig

jax.config.update("jax_platform_name", "cpu")

MAX_NEW = 3


@pytest.fixture(scope="module")
def moe_quantized():
    """CPU-sized Mixtral shape (8 experts top-2), W4A8-IS everywhere.

    capacity_factor = E/top_k in the smoke config means per-expert capacity
    always covers every routed token, so capacity drops can never occur and
    engine decode is comparable against a full-forward oracle.
    """
    cfg = get_arch("mixtral-8x7b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    qp = ptq.post_training_quantize(api, cfg, params, DEFAULT_RECIPE, None)
    return api, cfg, qp


def _reference_generate(api, cfg, params, prompt, n_new):
    """Greedy generation via full re-forward (no cache) — the oracle."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _, _ = api.apply(params, cfg,
                                 jnp.asarray([toks], jnp.int32),
                                 recipe=DEFAULT_RECIPE, mode="train")
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_moe_parity_and_zero_routed_expert(moe_quantized):
    """Engine tokens under the quantized-MoE pallas_interpret path match
    direct full-forward decoding, and the decode ticks include experts
    with zero routed rows (the ragged kernel's m-tile-skip case)."""
    api, cfg, qp = moe_quantized
    sc = ServeConfig(max_slots=2, max_seq=32, prefill_len=8,
                     max_new_tokens=MAX_NEW, kernel_mode="pallas_interpret")
    trace = moe.start_routing_trace()
    try:
        eng = Engine(api, cfg, qp, sc, recipe=DEFAULT_RECIPE)
        rng = np.random.default_rng(3)
        # ONE request: at most top_k=2 of 8 experts get rows per tick, so
        # every decode tick has zero-routed experts by construction
        prompt = rng.integers(0, cfg.vocab_size, size=8).tolist()
        rid = eng.submit(prompt)
        outs = eng.run()
    finally:
        moe.stop_routing_trace()

    # decode records follow the single prefill's (one per MoE layer)
    n_layers = cfg.num_layers
    decode_records = trace[n_layers:]
    assert len(decode_records) == (MAX_NEW - 1) * n_layers
    assert any(int(c) == 0 for r in decode_records
               for c in r["counts"][0]), \
        "expected a decode tick where an expert receives zero routed rows"

    pallas_cfg = eng.cfg  # cfg + kernel_mode from ServeConfig
    assert pallas_cfg.kernel_mode == "pallas_interpret"
    ref = _reference_generate(api, pallas_cfg, qp, prompt, MAX_NEW)
    assert outs[rid] == ref, (outs[rid], ref)


def test_engine_moe_decode_row_counts_do_not_retrace(moe_quantized):
    """Per-tick row_counts are traced operands: many decode ticks with
    changing routed dispatch must reuse ONE decode trace."""
    api, cfg, qp = moe_quantized
    sc = ServeConfig(max_slots=4, max_seq=32, prefill_len=8,
                     max_new_tokens=MAX_NEW, kernel_mode="pallas_interpret")
    eng = Engine(api, cfg, qp, sc, recipe=DEFAULT_RECIPE)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).tolist()
               for _ in range(4)]
    rids = [eng.submit(p) for p in prompts]
    outs = eng.run()
    assert set(outs) == set(rids)
    assert eng.ticks >= MAX_NEW - 1
    assert eng.prefill_traces == 1
    assert eng.decode_traces == 1, \
        f"decode retraced {eng.decode_traces}x — row_counts became static"


def test_engine_moe_reference_route_matches_interpret(moe_quantized):
    """Same engine, reference kernel mode: identical token streams (the
    serving benchmark's bit-exact claim, minimally)."""
    api, cfg, qp = moe_quantized
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).tolist()
               for _ in range(2)]
    outs = {}
    for mode in ("reference", "pallas_interpret"):
        sc = ServeConfig(max_slots=2, max_seq=32, prefill_len=8,
                         max_new_tokens=MAX_NEW, kernel_mode=mode)
        eng = Engine(api, cfg, qp, sc, recipe=DEFAULT_RECIPE)
        rids = [eng.submit(p) for p in prompts]
        got = eng.run()
        outs[mode] = [got[r] for r in rids]
    assert outs["reference"] == outs["pallas_interpret"]
