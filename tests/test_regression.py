"""benchmarks/regression.py: the perf-contract gate over two
``benchmarks.run --json`` documents."""
import json
import sys

import pytest

sys.path.insert(0, ".")  # benchmarks package lives at the repo root
from benchmarks import regression  # noqa: E402


def _doc(rows):
    return {"modules": ["m"], "fast": True, "provenance": {},
            "rows": rows, "metrics": {}}


def _row(name, us, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


BASE_ROWS = [
    _row("serving-moe/ragged-is", 5000.0,
         "CPU-proxy;arch=mixtral-smoke;E=8;top_k=2;ticks=9;tokens=36;"
         "tok_per_s=4.00;decode_traces=1;bit_exact_vs_reference=True"),
    _row("kernel/dense", 900.0, "E=8;C=16;K=256;N=256"),
    _row("kernel/tiny", 5.0, "E=1"),  # below the noise floor
]


@pytest.fixture
def paths(tmp_path):
    def write(name, rows):
        p = tmp_path / name
        p.write_text(json.dumps(_doc(rows)))
        return str(p)
    return write


def _run(base_path, cur_path, *extra):
    return regression.main(["--baseline", base_path, "--current",
                            cur_path, *extra])


class TestGate:
    def test_identical_passes(self, paths):
        b = paths("b.json", BASE_ROWS)
        c = paths("c.json", BASE_ROWS)
        assert _run(b, c) == 0

    def test_synthetically_slowed_row_fails(self, paths):
        slowed = json.loads(json.dumps(BASE_ROWS))
        slowed[1]["us_per_call"] = 900.0 * 5  # 5x > default 2x tolerance
        b = paths("b.json", BASE_ROWS)
        c = paths("c.json", slowed)
        assert _run(b, c) == 1

    def test_throughput_drop_fails(self, paths):
        slow = json.loads(json.dumps(BASE_ROWS))
        slow[0]["derived"] = slow[0]["derived"].replace(
            "tok_per_s=4.00", "tok_per_s=1.00")  # 4x drop
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", slow)) == 1

    def test_within_tolerance_passes(self, paths):
        near = json.loads(json.dumps(BASE_ROWS))
        near[1]["us_per_call"] = 900.0 * 1.5  # < 2x
        near[0]["derived"] = near[0]["derived"].replace(
            "tok_per_s=4.00", "tok_per_s=3.00")  # 25% drop < 50%
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", near)) == 0

    def test_noise_floor_row_ignored(self, paths):
        jitter = json.loads(json.dumps(BASE_ROWS))
        jitter[2]["us_per_call"] = 50.0  # 10x on a 5us row: scheduler noise
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", jitter)) == 0

    def test_missing_row_fails(self, paths):
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", BASE_ROWS[:2])) == 1

    def test_new_row_ok_but_error_row_fails(self, paths):
        extra = BASE_ROWS + [_row("kernel/new-coverage", 100.0, "E=2")]
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", extra)) == 0
        errored = BASE_ROWS + [_row("moe_e2e/ERROR", 0.0,
                                    "RuntimeError('boom')")]
        assert _run(paths("b2.json", BASE_ROWS),
                    paths("c2.json", errored)) == 1

    def test_bit_exact_flip_fails(self, paths):
        flipped = json.loads(json.dumps(BASE_ROWS))
        flipped[0]["derived"] = flipped[0]["derived"].replace(
            "bit_exact_vs_reference=True", "bit_exact_vs_reference=False")
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", flipped)) == 1

    def test_retrace_fails(self, paths):
        retraced = json.loads(json.dumps(BASE_ROWS))
        retraced[0]["derived"] = retraced[0]["derived"].replace(
            "decode_traces=1", "decode_traces=3")
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", retraced)) == 1

    def test_config_change_is_a_new_key(self, paths):
        # identity fields (E=) participate in the key: a changed config is
        # a disappeared baseline row, not a silent perf comparison
        changed = json.loads(json.dumps(BASE_ROWS))
        changed[1]["derived"] = "E=16;C=16;K=256;N=256"
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", changed)) == 1


class TestParsing:
    def test_parse_derived(self):
        d = regression.parse_derived(
            "CPU-proxy;E=8;tok_per_s=4.50;bit_exact_vs_dense=True")
        assert d == {"E": "8", "tok_per_s": "4.50",
                     "bit_exact_vs_dense": "True"}

    def test_row_key_excludes_measurements(self):
        a = _row("x/y", 1.0, "E=8;tok_per_s=4.00;ticks=9")
        b = _row("x/y", 2.0, "E=8;tok_per_s=9.99;ticks=4")
        assert regression.row_key(a) == regression.row_key(b)
        c = _row("x/y", 1.0, "E=16;tok_per_s=4.00")
        assert regression.row_key(a) != regression.row_key(c)

    def test_duplicate_names_disambiguated(self, tmp_path):
        p = tmp_path / "d.json"
        p.write_text(json.dumps(_doc([_row("x/y", 1.0, "E=8"),
                                      _row("x/y", 2.0, "E=8")])))
        rows = regression.load_rows(str(p))
        assert len(rows) == 2
