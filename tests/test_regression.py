"""benchmarks/regression.py: the perf-contract gate over two
``benchmarks.run --json`` documents."""
import json
import sys

import pytest

sys.path.insert(0, ".")  # benchmarks package lives at the repo root
from benchmarks import regression  # noqa: E402


def _doc(rows, metrics=None):
    return {"modules": ["m"], "fast": True, "provenance": {},
            "rows": rows, "metrics": metrics or {}}


def _engine_counters(outcomes, submitted):
    series = {f'outcome="{k}"': float(v) for k, v in outcomes.items()}
    return {"engine_request_outcomes_total": series,
            "engine_requests_total": {'event="submitted"': float(submitted)}}


def _row(name, us, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


BASE_ROWS = [
    _row("serving-moe/ragged-is", 5000.0,
         "CPU-proxy;arch=mixtral-smoke;E=8;top_k=2;ticks=9;tokens=36;"
         "tok_per_s=4.00;decode_traces=1;bit_exact_vs_reference=True"),
    _row("kernel/dense", 900.0, "E=8;C=16;K=256;N=256"),
    _row("kernel/tiny", 5.0, "E=1"),  # below the noise floor
]


@pytest.fixture
def paths(tmp_path):
    def write(name, rows):
        p = tmp_path / name
        p.write_text(json.dumps(_doc(rows)))
        return str(p)
    return write


def _run(base_path, cur_path, *extra):
    return regression.main(["--baseline", base_path, "--current",
                            cur_path, *extra])


class TestGate:
    def test_identical_passes(self, paths):
        b = paths("b.json", BASE_ROWS)
        c = paths("c.json", BASE_ROWS)
        assert _run(b, c) == 0

    def test_synthetically_slowed_row_fails(self, paths):
        slowed = json.loads(json.dumps(BASE_ROWS))
        slowed[1]["us_per_call"] = 900.0 * 5  # 5x > default 2x tolerance
        b = paths("b.json", BASE_ROWS)
        c = paths("c.json", slowed)
        assert _run(b, c) == 1

    def test_throughput_drop_fails(self, paths):
        slow = json.loads(json.dumps(BASE_ROWS))
        slow[0]["derived"] = slow[0]["derived"].replace(
            "tok_per_s=4.00", "tok_per_s=1.00")  # 4x drop
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", slow)) == 1

    def test_within_tolerance_passes(self, paths):
        near = json.loads(json.dumps(BASE_ROWS))
        near[1]["us_per_call"] = 900.0 * 1.5  # < 2x
        near[0]["derived"] = near[0]["derived"].replace(
            "tok_per_s=4.00", "tok_per_s=3.00")  # 25% drop < 50%
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", near)) == 0

    def test_noise_floor_row_ignored(self, paths):
        jitter = json.loads(json.dumps(BASE_ROWS))
        jitter[2]["us_per_call"] = 50.0  # 10x on a 5us row: scheduler noise
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", jitter)) == 0

    def test_missing_row_fails(self, paths):
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", BASE_ROWS[:2])) == 1

    def test_new_row_ok_but_error_row_fails(self, paths):
        extra = BASE_ROWS + [_row("kernel/new-coverage", 100.0, "E=2")]
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", extra)) == 0
        errored = BASE_ROWS + [_row("moe_e2e/ERROR", 0.0,
                                    "RuntimeError('boom')")]
        assert _run(paths("b2.json", BASE_ROWS),
                    paths("c2.json", errored)) == 1

    def test_bit_exact_flip_fails(self, paths):
        flipped = json.loads(json.dumps(BASE_ROWS))
        flipped[0]["derived"] = flipped[0]["derived"].replace(
            "bit_exact_vs_reference=True", "bit_exact_vs_reference=False")
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", flipped)) == 1

    def test_retrace_fails(self, paths):
        retraced = json.loads(json.dumps(BASE_ROWS))
        retraced[0]["derived"] = retraced[0]["derived"].replace(
            "decode_traces=1", "decode_traces=3")
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", retraced)) == 1

    def test_config_change_is_a_new_key(self, paths):
        # identity fields (E=) participate in the key: a changed config is
        # a disappeared baseline row, not a silent perf comparison
        changed = json.loads(json.dumps(BASE_ROWS))
        changed[1]["derived"] = "E=16;C=16;K=256;N=256"
        assert _run(paths("b.json", BASE_ROWS),
                    paths("c.json", changed)) == 1


class TestMetricsStructure:
    """Hard structural failures over the metric snapshots: nonzero error
    outcomes and request-conservation violations (ISSUE 10)."""

    def test_healthy_snapshot_passes(self, paths):
        m = {"serving_moe": {
            "counters": _engine_counters({"ok": 15, "error": 0}, 15)}}
        assert regression.metrics_failures(_doc([], m)) == []

    def test_error_outcome_fails(self, paths, tmp_path):
        m = {"serving_moe": {
            "counters": _engine_counters({"ok": 14, "error": 1}, 15)}}
        fails = regression.metrics_failures(_doc([], m))
        assert len(fails) == 1 and "error" in fails[0]
        # and it gates through main(): same rows, poisoned metrics
        b = tmp_path / "b.json"
        c = tmp_path / "c.json"
        b.write_text(json.dumps(_doc(BASE_ROWS)))
        c.write_text(json.dumps(_doc(BASE_ROWS, m)))
        assert _run(str(b), str(c)) == 1

    def test_conservation_violation_fails(self):
        # 15 submitted but only 14 accounted for: a lost request
        m = {"serving_moe": {
            "counters": _engine_counters({"ok": 14}, 15)}}
        fails = regression.metrics_failures(_doc([], m))
        assert len(fails) == 1 and "conservation" in fails[0]
        # double retire (16 > 15) fails too
        m2 = {"serving_moe": {
            "counters": _engine_counters({"ok": 16}, 15)}}
        assert len(regression.metrics_failures(_doc([], m2))) == 1

    def test_standalone_snapshot_shape(self):
        # benchmarks.serving_moe --json writes ONE top-level snapshot
        doc = {"rows": [], "metrics": {
            "counters": _engine_counters({"ok": 3, "error": 2}, 5)}}
        fails = regression.metrics_failures(doc)
        assert len(fails) == 1 and "error" in fails[0]

    def test_non_engine_metrics_ignored(self):
        doc = {"rows": [], "metrics": {
            "kernels": {"counters": {"qgemm_calls_total": {"": 7.0}}},
            "quant": None}}
        assert regression.metrics_failures(doc) == []
        assert regression.metrics_failures({"rows": []}) == []


class TestParsing:
    def test_parse_derived(self):
        d = regression.parse_derived(
            "CPU-proxy;E=8;tok_per_s=4.50;bit_exact_vs_dense=True")
        assert d == {"E": "8", "tok_per_s": "4.50",
                     "bit_exact_vs_dense": "True"}

    def test_row_key_excludes_measurements(self):
        a = _row("x/y", 1.0, "E=8;tok_per_s=4.00;ticks=9")
        b = _row("x/y", 2.0, "E=8;tok_per_s=9.99;ticks=4")
        assert regression.row_key(a) == regression.row_key(b)
        c = _row("x/y", 1.0, "E=16;tok_per_s=4.00")
        assert regression.row_key(a) != regression.row_key(c)

    def test_duplicate_names_disambiguated(self, tmp_path):
        p = tmp_path / "d.json"
        p.write_text(json.dumps(_doc([_row("x/y", 1.0, "E=8"),
                                      _row("x/y", 2.0, "E=8")])))
        rows = regression.load_rows(str(p))
        assert len(rows) == 2
