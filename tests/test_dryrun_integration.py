"""Integration: the multi-pod dry-run machinery end to end (subprocess —
the 512 placeholder devices must be configured before jax initializes,
which the already-running test process cannot do)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow  # subprocess 512-device lower+compile (~40 s)
@pytest.mark.parametrize("arch,shape", [("whisper-tiny", "train_4k")])
def test_dryrun_cell_compiles_on_512_devices(tmp_path, arch, shape):
    out = tmp_path / "cell.jsonl"
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape,
         "--multi-pod", "single", "--out", str(out)],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok", rec
    assert rec["mesh"] == "16x16"
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["argument_bytes"] > 0
    # collective inventory parsed from the compiled HLO
    assert rec["collectives"]["total_wire_bytes"] > 0
