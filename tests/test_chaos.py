"""Serving fault-injection suite (``pytest -m chaos``).

Drives ``repro.serving.chaos`` against the real engine on a tiny dense
model and asserts the ISSUE-10 robustness contract: co-batched requests
stay bit-exact under injected faults, steady-state decode holds ONE
trace per established kernel route (``decode_traces == 1 + fallbacks``),
and the conservation law — every submitted rid ends in exactly one
terminal outcome — survives NaNs, kernel exceptions, deadline overruns,
queue floods, cancellation, and engine aborts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.nn import spec as S
from repro.serving.chaos import (ChaosConfig, ChaosMonkey, KernelFault,
                                 NanFault, SlowTick, flood)
from repro.serving.engine import OUTCOMES, Engine, EngineAborted, ServeConfig

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.chaos


class StepClock:
    """Monotonic stub: every reading advances by ``step``; ``advance``
    jumps time (the chaos SlowTick sleep_fn)."""

    def __init__(self, step: float = 0.0):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t

    def advance(self, s: float) -> None:
        self.t += s


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      dtype="float32", q_chunk=16, kv_chunk=16, remat=False)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    return api, cfg, params


def _prompts(n, seed=0, size=8):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 64, size=size).tolist() for _ in range(n)]


def _engine(tiny, **sc_kw):
    api, cfg, params = tiny
    sc_kw.setdefault("max_slots", 3)
    sc_kw.setdefault("max_seq", 64)
    sc_kw.setdefault("prefill_len", 8)
    sc_kw.setdefault("max_new_tokens", 6)
    return Engine(api, cfg, params, ServeConfig(**sc_kw))


def _conserved(reg: obs.Registry, eng: Engine) -> None:
    """The conservation law, checked from the metrics snapshot AND the
    engine's own bookkeeping: every submitted rid has exactly one
    terminal outcome, no slot is left active, nothing is queued."""
    c = reg.snapshot()["counters"]
    outcomes = c["engine_request_outcomes_total"]
    submitted = c["engine_requests_total"]['event="submitted"']
    assert sum(outcomes.values()) == submitted
    assert len(eng.outcomes) == submitted
    assert set(eng.outcomes.values()) <= set(OUTCOMES)


def _baseline(tiny, prompts, **sc_kw):
    """Fault-free token streams for bit-exactness comparisons."""
    with obs.use_registry(obs.Registry()):
        eng = _engine(tiny, **sc_kw)
        rids = [eng.submit(p) for p in prompts]
        outs = eng.run()
        eng.close()
    return {r: outs[r] for r in rids}


# ---------------------------------------------------------------------------
# NaN quarantine
# ---------------------------------------------------------------------------


def test_nan_quarantine_cobatch_bit_exact(tiny):
    """A poisoned slot retires with outcome=nan; its co-batched
    neighbours finish BIT-EXACT vs a fault-free run, on one decode
    trace."""
    prompts = _prompts(3, seed=0)
    ref = _baseline(tiny, prompts)
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny)
        monkey = ChaosMonkey(ChaosConfig(
            nan_logits=(NanFault(tick=2, rid=1),))).install(eng)
        rids = [eng.submit(p) for p in prompts]
        outs = eng.run()
        eng.close()
    assert monkey.injected == [{"kind": "nan", "tick": 2, "rid": 1,
                                "slot": 1}]
    assert eng.outcome(1) == "nan"
    # the poisoned request delivers its pre-fault partial stream (the
    # garbage token is never appended) and it is a prefix of the
    # fault-free stream
    assert outs[1] == ref[1][:len(outs[1])]
    assert len(outs[1]) < len(ref[1])
    # co-batched requests: bit-exact, full length
    for r in (0, 2):
        assert eng.outcome(r) == "ok"
        assert outs[r] == ref[r]
    assert eng.decode_traces == 1 and eng.fallbacks == 0
    _conserved(reg, eng)
    c = reg.snapshot()["counters"]
    assert c["engine_request_outcomes_total"]['outcome="nan"'] == 1
    assert c["engine_request_outcomes_total"]['outcome="ok"'] == 2


def test_nan_slot_reuse_after_quarantine(tiny):
    """A quarantined slot is freed and reused: the next request admits
    into the SAME slot and serves a clean, bit-exact stream."""
    api, cfg, params = tiny
    prompts = _prompts(2, seed=3)
    ref = _baseline(tiny, prompts)
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, max_slots=1)
        # poison the single slot's first decode tick: rid 0 dies, rid 1
        # then admits into the SAME slot and must be unaffected
        ChaosMonkey(ChaosConfig(
            nan_logits=(NanFault(tick=0, rid=0),))).install(eng)
        for p in prompts:
            eng.submit(p)
        outs = eng.run()
        eng.close()
    assert eng.outcome(0) == "nan"
    assert eng.outcome(1) == "ok"
    assert outs[1] == ref[1]
    _conserved(reg, eng)


# ---------------------------------------------------------------------------
# Kernel faults, retry, breaker
# ---------------------------------------------------------------------------


def test_kernel_fault_below_threshold_retries_bit_exact(tiny):
    """A transient decode exception is retried WITHOUT advancing the
    tick or the sampling stream: final streams bit-exact vs fault-free,
    still one trace, no fallback."""
    prompts = _prompts(3, seed=1)
    ref = _baseline(tiny, prompts)
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, breaker_threshold=3)
        monkey = ChaosMonkey(ChaosConfig(
            kernel_failures=(KernelFault(tick=1, count=2),))).install(eng)
        rids = [eng.submit(p) for p in prompts]
        outs = eng.run()
        eng.close()
    assert [e["kind"] for e in monkey.injected] == ["kernel", "kernel"]
    assert {r: outs[r] for r in rids} == ref
    assert all(eng.outcome(r) == "ok" for r in rids)
    assert eng.decode_traces == 1 and eng.fallbacks == 0
    c = reg.snapshot()["counters"]
    assert c["engine_kernel_failures_total"]['phase="decode"'] == 2
    _conserved(reg, eng)


def test_breaker_trips_fallback_and_reestablishes(tiny):
    """breaker_threshold consecutive decode failures trip the fallback:
    kernel_mode swaps, decode re-jits EXACTLY once more
    (decode_traces == 1 + fallbacks), and every request still finishes
    ok bit-exact (the tiny model is unquantized, so both routes compute
    the same graph)."""
    prompts = _prompts(3, seed=2)
    ref = _baseline(tiny, prompts, kernel_mode="reference")
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, kernel_mode="pallas_interpret",
                      fallback_kernel_mode="reference",
                      breaker_threshold=2)
        ChaosMonkey(ChaosConfig(
            kernel_failures=(KernelFault(tick=1, count=2),))).install(eng)
        rids = [eng.submit(p) for p in prompts]
        outs = eng.run()
        eng.close()
    assert eng.fallbacks == 1
    assert eng.decode_traces == 1 + eng.fallbacks == 2
    assert eng.cfg.kernel_mode == "reference"
    assert {r: outs[r] for r in rids} == ref
    c = reg.snapshot()["counters"]
    assert c["engine_fallback_events_total"][
        'reason="decode_exception"'] == 1
    fallbacks = [e for e in reg.events() if e.get("ev") == "fallback"]
    assert fallbacks and fallbacks[0]["from"] == "pallas_interpret" \
        and fallbacks[0]["to"] == "reference"
    _conserved(reg, eng)


def test_breaker_exhausted_aborts_with_error_outcomes(tiny):
    """With no fallback route left, a persistent failure aborts the
    engine: EngineAborted propagates, every in-flight request retires
    with outcome=error, and NO slot stays active (teardown-under-fault
    contract for the driver's finally-flush)."""
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, fallback_kernel_mode=None, breaker_threshold=2)
        ChaosMonkey(ChaosConfig(
            kernel_failures=(KernelFault(tick=0, count=99),))).install(eng)
        rids = [eng.submit(p) for p in _prompts(3, seed=4)]
        with pytest.raises(EngineAborted, match="no fallback route"):
            eng.run()
    assert all(eng.outcome(r) == "error" for r in rids)
    assert not any(s.active for s in eng.slots)
    assert eng.queue == []
    _conserved(reg, eng)
    # the abort is a distinct timeline marker
    names = [e["name"] for e in obs.timeline.trace_events(reg.events())]
    assert any(n.startswith("engine abort:") for n in names)
    assert any(n.startswith("kernel_failure:decode") for n in names)
    eng.close()
    eng.close()  # idempotent


def test_nan_streak_trips_breaker(tiny):
    """Persistently poisoned logits are a quant-health alarm: after
    breaker_threshold consecutive poisoned ticks the engine degrades to
    the fallback route instead of burning ticks on NaNs."""
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, max_slots=2, kernel_mode="pallas_interpret",
                      fallback_kernel_mode="reference",
                      breaker_threshold=2, max_new_tokens=12)
        # poison every active slot for two consecutive ticks: exactly
        # the streak that trips the breaker (a third poisoned tick after
        # the fallback would exhaust the route chain and abort)
        ChaosMonkey(ChaosConfig(nan_logits=tuple(
            NanFault(tick=t) for t in (1, 2)))).install(eng)
        for p in _prompts(6, seed=5):
            eng.submit(p)
        eng.run()
        eng.close()
    assert eng.fallbacks == 1
    assert eng.decode_traces == 2
    c = reg.snapshot()["counters"]
    assert c["engine_fallback_events_total"]['reason="nan_logits"'] == 1
    assert c["engine_request_outcomes_total"]['outcome="nan"'] > 0
    _conserved(reg, eng)


def test_external_breaker_trip(tiny):
    """External quant-health monitors can force the fallback via
    Engine.trip_breaker."""
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, kernel_mode="pallas_interpret",
                      fallback_kernel_mode="reference")
        eng.trip_breaker("alpha_cap_alarm")
        rids = [eng.submit(p) for p in _prompts(2, seed=6)]
        outs = eng.run()
        eng.close()
    assert eng.fallbacks == 1 and eng.cfg.kernel_mode == "reference"
    assert all(eng.outcome(r) == "ok" for r in rids)
    assert reg.snapshot()["counters"]["engine_fallback_events_total"][
        'reason="alpha_cap_alarm"'] == 1
    assert len(outs) == 2
    _conserved(reg, eng)


# ---------------------------------------------------------------------------
# Deadlines, cancellation, backpressure
# ---------------------------------------------------------------------------


def test_deadline_timeout_active_and_queued(tiny):
    """Deadline overruns retire with outcome=timeout — mid-decode with
    partial output, and straight from the queue for requests that never
    reach a slot (driven deterministically by an injected clock)."""
    clock = StepClock(step=0.01)
    reg = obs.Registry(clock=clock)
    with obs.use_registry(reg):
        eng = _engine(tiny, max_slots=1, max_new_tokens=30,
                      deadline_s=1.0)
        rid_a, rid_b = (eng.submit(p) for p in _prompts(2, seed=7))
        # jump to just shy of the deadlines: rid_a admits and overruns
        # within its first ticks; rid_b expires straight from the queue
        clock.advance(0.9)
        outs = eng.run()
        eng.close()
    assert eng.outcome(rid_a) == "timeout"
    assert 0 < len(outs[rid_a]) < 30  # partial stream delivered
    # the queued request's deadline expired before the slot freed
    assert eng.outcome(rid_b) == "timeout"
    retires = {e["rid"]: e for e in reg.events()
               if e.get("ev") == "retire"}
    assert retires[rid_b].get("where") == "queued"
    _conserved(reg, eng)


def test_cancel_queued_and_active(tiny):
    clock = StepClock(step=0.0)
    reg = obs.Registry(clock=clock)
    with obs.use_registry(reg):
        eng = _engine(tiny, max_slots=1, max_new_tokens=10)
        rid_a, rid_b = (eng.submit(p) for p in _prompts(2, seed=8))
        assert eng.cancel(rid_b) is True          # queued -> cancelled
        assert eng.cancel(rid_b) is False         # already terminal
        assert eng.cancel(999) is False           # unknown rid
        eng.run(max_ticks=3)                      # rid_a still active
        assert eng.outcome(rid_a) is None
        assert eng.cancel(rid_a) is True          # active -> cancelled
        assert not any(s.active for s in eng.slots)  # slot freed
        eng.close()
    assert eng.outcome(rid_a) == "cancelled"
    assert eng.outcome(rid_b) == "cancelled"
    assert len(eng.outputs[rid_a]) > 0            # partial tokens kept
    _conserved(reg, eng)


def test_queue_flood_backpressure(tiny):
    """max_queue bounds admission: the surplus of a flood is REJECTED
    (terminal outcome, no silent growth) and the accepted requests all
    finish ok."""
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, max_slots=2, max_queue=3)
        rids = flood(eng, 8, prompt=[1, 2, 3])
        rejected = [r for r in rids if eng.outcome(r) == "rejected"]
        assert len(rejected) == 5  # 8 submitted, queue bound 3
        outs = eng.run()
        eng.close()
    accepted = [r for r in rids if r not in rejected]
    assert all(eng.outcome(r) == "ok" for r in accepted)
    assert set(outs) == set(accepted)
    c = reg.snapshot()["counters"]
    assert c["engine_request_outcomes_total"]['outcome="rejected"'] == 5
    retires = [e for e in reg.events() if e.get("ev") == "retire"
               and e.get("outcome") == "rejected"]
    assert all(e["reason"] == "queue_full" for e in retires)
    _conserved(reg, eng)


def test_overlength_prompt_rejected_not_truncated(tiny):
    """Prompts longer than prefill_len are rejected with a structured
    reason — silent clipping only happens under the explicit
    truncate_prompts opt-in."""
    long_prompt = list(range(1, 20))  # 19 > prefill_len=8
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny)
        rid = eng.submit(long_prompt)
        assert eng.outcome(rid) == "rejected"
        assert eng.queue == []
        ev = [e for e in reg.events() if e.get("ev") == "retire"][-1]
        assert ev["reason"] == "prompt_overlength"
        assert eng.run() == {}  # nothing admitted
        eng.close()
        _conserved(reg, eng)
    # explicit opt-in: same prompt is clipped to prefill_len and served
    with obs.use_registry(obs.Registry()):
        eng2 = _engine(tiny, truncate_prompts=True)
        rid2 = eng2.submit(long_prompt)
        outs = eng2.run()
        eng2.close()
    assert eng2.outcome(rid2) == "ok"
    assert len(outs[rid2]) == 6


# ---------------------------------------------------------------------------
# Watchdog, double-retire guard, mixed drill
# ---------------------------------------------------------------------------


def test_slow_tick_watchdog(tiny):
    """An injected stall inside the decode window trips the Heartbeat
    straggler path: engine_slow_ticks_total + a slow_tick timeline
    marker (deterministic via the fake clock as both registry clock and
    chaos sleep)."""
    clock = StepClock(step=0.01)
    reg = obs.Registry(clock=clock)
    with obs.use_registry(reg):
        eng = _engine(tiny, max_new_tokens=12, slow_tick_factor=3.0)
        monkey = ChaosMonkey(
            ChaosConfig(slow_ticks=(SlowTick(tick=8, seconds=5.0),)),
            sleep_fn=clock.advance).install(eng)
        eng.submit(_prompts(1, seed=9)[0])
        eng.run()
        eng.close()
    assert [e["kind"] for e in monkey.injected] == ["slow"]
    c = reg.snapshot()["counters"]
    assert c["engine_slow_ticks_total"][""] == 1
    slow = [e for e in reg.events() if e.get("ev") == "slow_tick"]
    assert slow and slow[0]["tick"] == 8
    names = [e["name"] for e in obs.timeline.trace_events(reg.events())]
    assert "slow_tick" in names
    _conserved(reg, eng)


def test_double_retire_raises(tiny):
    """The _finish chokepoint enforces the no-double-retire half of the
    conservation law."""
    with obs.use_registry(obs.Registry()):
        eng = _engine(tiny)
        rid = eng.submit([1, 2, 3])
        eng.run()
        assert eng.outcome(rid) == "ok"
        with pytest.raises(RuntimeError, match="already terminal"):
            eng._finish(rid, "error")
        eng.close()


def test_mixed_fault_drill_conservation(tiny):
    """Everything at once — NaN, transient kernel fault, flood-rejects,
    a cancel, an over-length reject — and the books still balance, on
    one decode trace."""
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, max_slots=2, max_queue=4, breaker_threshold=5)
        ChaosMonkey(ChaosConfig(
            nan_logits=(NanFault(tick=1, rid=0),),
            kernel_failures=(KernelFault(tick=3, count=1),))).install(eng)
        rids = flood(eng, 6, prompt=[4, 5, 6])   # 2 rejected (queue=4)
        over = eng.submit(list(range(30)))       # rejected: over-length
        cancelled = next(r for r in rids if eng.outcome(r) is None
                         and r != rids[0])
        eng.cancel(cancelled)
        eng.run()
        eng.close()
    assert eng.decode_traces == 1 and eng.fallbacks == 0
    assert eng.outcome(over) == "rejected"
    assert eng.outcome(cancelled) == "cancelled"
    assert eng.outcome(rids[0]) == "nan"
    from collections import Counter
    tally = Counter(eng.outcomes.values())
    assert tally["rejected"] == 3 and tally["cancelled"] == 1 \
        and tally["nan"] == 1 and tally["error"] == 0
    assert tally["ok"] == 7 - 3 - 1 - 1
    _conserved(reg, eng)


# ---------------------------------------------------------------------------
# Teardown under fault
# ---------------------------------------------------------------------------


def test_teardown_removes_routing_sink_and_is_idempotent(tiny):
    from repro.models import moe

    with obs.use_registry(obs.Registry()):
        eng = _engine(tiny)
        assert eng._routing_sink in moe._ROUTING_SINKS
        eng.close()
        assert eng._routing_sink not in moe._ROUTING_SINKS
        eng.close()  # second close is a no-op, not an error


def test_crashed_run_flushes_conserved_telemetry(tiny, tmp_path):
    """The serve.py failure-path contract: after a crashed run() the
    event log + snapshot still flush, the snapshot satisfies the
    conservation law, and the trace is well-formed with error markers."""
    reg = obs.Registry()
    with obs.use_registry(reg):
        eng = _engine(tiny, fallback_kernel_mode=None, breaker_threshold=1)
        ChaosMonkey(ChaosConfig(
            kernel_failures=(KernelFault(tick=1, count=9),))).install(eng)
        for p in _prompts(2, seed=10):
            eng.submit(p)
        with pytest.raises(EngineAborted):
            eng.run()
        # the driver's finally-block equivalents:
        mpath, tpath = tmp_path / "m.jsonl", tmp_path / "t.json"
        n = reg.write_events_jsonl(str(mpath))
        assert n > 0 and mpath.exists()
        obs.write_trace(str(tpath), reg)
        eng.close()
    import json
    snap = json.loads(mpath.read_text().splitlines()[-1])["snapshot"]
    c = snap["counters"]
    outcomes = c["engine_request_outcomes_total"]
    assert outcomes['outcome="error"'] == 2
    assert sum(outcomes.values()) == \
        c["engine_requests_total"]['event="submitted"']
    names = [e["name"]
             for e in json.loads(tpath.read_text())["traceEvents"]]
    assert any(n.endswith("retire:error") for n in names)
