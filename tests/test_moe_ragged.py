"""Ragged scalar-prefetch grouped kernel validation.

The invariant: for any dispatch buffer whose rows at or past each expert's
``row_counts[e]`` are zero-filled, the ragged kernel (scalar-prefetch
m-tile skipping + fused act-quant) must be BIT-EXACT against the dense
capacity-padded grouped kernel fed the externally-quantized activations —
for every variant (integer-scale, float-scale incl. coarse, W4A16),
including per-expert heuristic alphas, for the edge cases that exercise the
grid clamping: an expert with 0 routed tokens, counts that are not a
multiple of the m-block, and all experts exactly at capacity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integer_scale as isc
from repro.core import packing, qlinear, quant
from repro.core.recipe import QuantSpec
from repro.kernels.act_quant import act_quant
from repro.kernels.moe_gemm import (fg_grouped_gemm_float_scale,
                                    fg_grouped_gemm_float_scale_ragged,
                                    fg_grouped_gemm_integer_scale,
                                    fg_grouped_gemm_integer_scale_ragged,
                                    grouped_w4a16_gemm,
                                    grouped_w4a16_gemm_ragged,
                                    ragged_tile_stats)

jax.config.update("jax_platform_name", "cpu")


def _mk_experts(seed, E, K, N, g, w_bits=4, amplifier="heuristic+6"):
    keys = jax.random.split(jax.random.PRNGKey(seed), E)
    packed, iscale, fscale, alphas = [], [], [], []
    for e in range(E):
        # magnitude spread so heuristic amplifiers differ across experts
        w = jax.random.normal(keys[e], (K, N)) * 0.05 * (4.0 ** (e % 3))
        qw = quant.quantize_weight(w, w_bits, g)
        isw = isc.integerize(qw, amplifier)
        packed.append(packing.pack_int4(qw.qvalue) if w_bits == 4
                      else qw.qvalue)
        iscale.append(isw.int_scale)
        fscale.append(qw.scale)
        alphas.append(float(isw.alpha))
    return (jnp.stack(packed), jnp.stack(iscale), jnp.stack(fscale), alphas)


def _ragged_acts(seed, E, C, K, counts):
    """Raw dispatch-style buffer: rows at or past counts[e] zero-filled."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (E, C, K))
    mask = jnp.arange(C)[None, :, None] < jnp.asarray(counts)[:, None, None]
    return jnp.where(mask, x, 0.0)


def _dense_quant(x):
    """The pre-ragged dispatch: one dense act_quant over (E*C, K)."""
    E, C, K = x.shape
    xq, sa = act_quant(x.reshape(E * C, K), interpret=True)
    return xq.reshape(E, C, K), sa.reshape(E, C, 1)


# counts exercising: empty expert, non-multiple-of-bm, at-capacity
COUNT_CASES = [
    ([0, 24, 24], "empty expert"),
    ([5, 13, 21], "counts not a multiple of the m-block"),
    ([24, 24, 24], "all experts at capacity"),
]


@pytest.mark.parametrize("counts,label", COUNT_CASES)
def test_ragged_is_bit_exact_vs_dense_grouped(counts, label):
    E, C, K, N, g = 3, 24, 256, 128, 128
    qv, iscale, _, alphas = _mk_experts(0, E, K, N, g)
    assert len(set(alphas)) > 1, "want distinct per-expert amplifiers"
    al = jnp.asarray(alphas, jnp.float32)
    x = _ragged_acts(1, E, C, K, counts)
    xq, sa = _dense_quant(x)
    y_dense = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g, alpha=al, interpret=True)
    y_rag = fg_grouped_gemm_integer_scale_ragged(
        x, jnp.asarray(counts, jnp.int32), qv, iscale, group_size=g,
        alpha=al, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_dense),
                                  err_msg=label)


@pytest.mark.parametrize("counts,label", COUNT_CASES[:2])
def test_ragged_fs_bit_exact_vs_dense_grouped(counts, label):
    E, C, K, N, g = 3, 24, 256, 128, 128
    qv, _, fscale, _ = _mk_experts(2, E, K, N, g)
    x = _ragged_acts(3, E, C, K, counts)
    xq, sa = _dense_quant(x)
    y_dense = fg_grouped_gemm_float_scale(
        xq, sa, qv, fscale, group_size=g, interpret=True)
    y_rag = fg_grouped_gemm_float_scale_ragged(
        x, jnp.asarray(counts, jnp.int32), qv, fscale, group_size=g,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_dense),
                                  err_msg=label)


def test_ragged_fs_coarse_bit_exact_vs_dense_grouped():
    """Coarse per-channel scales (group_size=-1) take a distinct branch
    (one scale row reused for every k-block) — same ragged invariant."""
    E, C, K, N = 3, 24, 256, 128
    packs, scales = [], []
    for e in range(E):
        w = jax.random.normal(jax.random.PRNGKey(40 + e), (K, N)) * 0.05
        qw = quant.quantize_weight(w, 4, -1)
        packs.append(packing.pack_int4(qw.qvalue))
        scales.append(qw.scale[None, :])  # (1, N) coarse
    qv, cscale = jnp.stack(packs), jnp.stack(scales)
    counts = [0, 11, 24]
    x = _ragged_acts(41, E, C, K, counts)
    xq, sa = _dense_quant(x)
    y_dense = fg_grouped_gemm_float_scale(
        xq, sa, qv, cscale, group_size=-1, interpret=True)
    y_rag = fg_grouped_gemm_float_scale_ragged(
        x, jnp.asarray(counts, jnp.int32), qv, cscale, group_size=-1,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_dense))


def test_ragged_w4a16_bit_exact_vs_dense_grouped():
    E, C, K, N, g = 3, 24, 256, 256, 128
    qv, _, fscale, _ = _mk_experts(4, E, K, N, g)
    counts = [0, 7, 24]
    x = _ragged_acts(5, E, C, K, counts).astype(jnp.bfloat16)
    y_dense = grouped_w4a16_gemm(x, qv, fscale, group_size=g,
                                 interpret=True)
    y_rag = grouped_w4a16_gemm_ragged(
        x, jnp.asarray(counts, jnp.int32), qv, fscale, group_size=g,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_dense))


def test_ragged_outputs_zero_past_counts():
    """Skipped m-tiles must write exact zeros, not stale garbage."""
    E, C, K, N, g = 2, 32, 256, 128, 128
    qv, iscale, _, _ = _mk_experts(6, E, K, N, g, amplifier=1024)
    counts = [9, 0]
    x = _ragged_acts(7, E, C, K, counts)
    y = fg_grouped_gemm_integer_scale_ragged(
        x, jnp.asarray(counts, jnp.int32), qv, iscale, group_size=g,
        alpha=1024.0, interpret=True)
    for e, c in enumerate(counts):
        np.testing.assert_array_equal(
            np.asarray(y[e, c:]), np.zeros((C - c, N), np.float32))


def test_ragged_block_shape_sweep():
    """m-tile skipping must be invariant to BlockSpec tiling choices."""
    E, C, K, N, g = 2, 20, 512, 256, 128
    qv, iscale, _, alphas = _mk_experts(8, E, K, N, g)
    al = jnp.asarray(alphas, jnp.float32)
    counts = jnp.asarray([3, 17], jnp.int32)
    x = _ragged_acts(9, E, C, K, [3, 17])
    ref = fg_grouped_gemm_integer_scale_ragged(
        x, counts, qv, iscale, group_size=g, alpha=al, interpret=True)
    for bm, bn, bk in [(8, 128, 128), (16, 256, 256), (128, 128, 512)]:
        y = fg_grouped_gemm_integer_scale_ragged(
            x, counts, qv, iscale, group_size=g, alpha=al,
            bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref),
                                      err_msg=f"blocks={(bm, bn, bk)}")


def test_ragged_row_counts_none_matches_dense():
    """row_counts=None treats every capacity slot as routed (fused quant
    only — must still equal the unfused dense grouped kernel)."""
    E, C, K, N, g = 2, 16, 256, 128, 128
    qv, iscale, _, _ = _mk_experts(10, E, K, N, g, amplifier=1024)
    x = jax.random.normal(jax.random.PRNGKey(11), (E, C, K))
    xq, sa = _dense_quant(x)
    y_dense = fg_grouped_gemm_integer_scale(
        xq, sa, qv, iscale, group_size=g, alpha=1024.0, interpret=True)
    y_rag = fg_grouped_gemm_integer_scale_ragged(
        x, None, qv, iscale, group_size=g, alpha=1024.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_rag), np.asarray(y_dense))


def test_ragged_counts_clamped_to_capacity():
    """bincount counts can exceed capacity (dropped tokens) — the wrapper
    must clamp instead of indexing out of range."""
    E, C, K, N, g = 2, 16, 256, 128, 128
    qv, iscale, _, _ = _mk_experts(12, E, K, N, g, amplifier=1024)
    x = jax.random.normal(jax.random.PRNGKey(13), (E, C, K))
    y_over = fg_grouped_gemm_integer_scale_ragged(
        x, jnp.asarray([100, 16], jnp.int32), qv, iscale, group_size=g,
        alpha=1024.0, interpret=True)
    y_full = fg_grouped_gemm_integer_scale_ragged(
        x, jnp.asarray([16, 16], jnp.int32), qv, iscale, group_size=g,
        alpha=1024.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_over), np.asarray(y_full))


def test_qgemm_grouped_row_counts_matches_reference():
    """ops.qgemm_grouped (fused ragged path) == vmapped reference on a
    ragged dispatch buffer, through the qlinear entry point."""
    E, C, K, N, g = 4, 16, 256, 256, 128
    qv, iscale, _, alphas = _mk_experts(14, E, K, N, g)
    params = {"qvalue": qv, "scale": iscale,
              "alpha": jnp.asarray(alphas, jnp.float32)}
    spec = QuantSpec(amplifier="heuristic+6")
    counts = jnp.asarray([0, 5, 16, 11], jnp.int32)
    x = _ragged_acts(15, E, C, K, [0, 5, 16, 11])
    y_pal = qlinear.grouped_linear_apply(params, x, spec,
                                         row_counts=counts,
                                         mode="pallas_interpret")
    y_ref = qlinear.grouped_linear_apply(params, x, spec, mode="reference")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-2)


def test_ragged_tile_stats_accounting():
    stats = ragged_tile_stats([0, 5, 128, 200], C=128, bm=128)
    assert stats == {"bm": 128, "dense_m_tiles": 4, "ragged_m_tiles": 3}
    stats = ragged_tile_stats([0, 5, 9], C=24, bm=8)
    assert stats["dense_m_tiles"] == 9
    assert stats["ragged_m_tiles"] == 0 + 1 + 2
