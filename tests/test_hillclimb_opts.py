"""Correctness of the §Perf hillclimb optimizations (EXPERIMENTS.md):
chunkwise-parallel mLSTM, int8 MoE dispatch, int8 KV cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st  # optional dev dep shim

from repro.models.registry import get_arch, get_model
from repro.models.xlstm import _mlstm_cell, _mlstm_chunked
from repro.nn import spec as S

jax.config.update("jax_platform_name", "cpu")


def _seq_ref(q, k, v, ir, fr, C0, n0, m0):
    def step(c, t):
        return _mlstm_cell(c, t)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, ir, fr))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([2, 4, 8, 16]),
       s=st.sampled_from([16, 32]))
def test_chunked_mlstm_exact_vs_sequential(seed, chunk, s):
    """The chunkwise closed form must equal the step recurrence (f32)."""
    B, H, dh = 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, s, H, dh))
    k = jax.random.normal(ks[1], (B, s, H, dh))
    v = jax.random.normal(ks[2], (B, s, H, dh))
    ir = jax.random.normal(ks[3], (B, s, H)) * 3
    fr = jax.random.normal(ks[4], (B, s, H)) * 3
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    m0 = jnp.zeros((B, H))
    h_s, (C_s, n_s, m_s) = _seq_ref(q, k, v, ir, fr, C0, n0, m0)
    h_c, (C_c, n_c, m_c) = _mlstm_chunked(q, k, v, ir, fr, C0, n0, m0,
                                          chunk)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(C_c), np.asarray(C_s),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_s),
                               rtol=1e-4, atol=1e-5)


def test_chunked_mlstm_nonzero_initial_state():
    """Carrying state across calls (prefill -> prefill continuation)."""
    B, s, H, dh = 1, 12, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    q = jax.random.normal(ks[0], (B, s, H, dh))
    k = jax.random.normal(ks[1], (B, s, H, dh))
    v = jax.random.normal(ks[2], (B, s, H, dh))
    ir = jax.random.normal(ks[3], (B, s, H))
    fr = jax.random.normal(ks[4], (B, s, H))
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    m0 = jnp.zeros((B, H))
    h_full, _ = _seq_ref(q, k, v, ir, fr, C0, n0, m0)
    # first half sequential, second half chunked from the carried state
    h1, (C1, n1, m1) = _seq_ref(q[:, :6], k[:, :6], v[:, :6], ir[:, :6],
                                fr[:, :6], C0, n0, m0)
    h2, _ = _mlstm_chunked(q[:, 6:], k[:, 6:], v[:, 6:], ir[:, 6:],
                           fr[:, 6:], C1, n1, m1, chunk=3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full[:, 6:]),
                               rtol=1e-4, atol=1e-5)


def test_chunked_model_close_to_sequential():
    cfg = get_arch("xlstm-1.3b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    l_seq, _, _ = api.apply(params, cfg, toks, mode="train")
    cfg_c = dataclasses.replace(cfg, mlstm_impl="chunked", chunk_size=16)
    l_chk, _, _ = api.apply(params, cfg_c, toks, mode="train")
    # bf16 activations: different-but-equivalent op orders
    rel = float(jnp.linalg.norm(l_chk - l_seq) / jnp.linalg.norm(l_seq))
    assert rel < 0.02, rel


def test_int8_moe_dispatch_close_to_bf16():
    cfg = get_arch("phi3.5-moe-42b-a6.6b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0,
                              cfg.vocab_size)
    l_ref, _, _ = api.apply(params, cfg, toks, mode="train")
    cfg_i8 = dataclasses.replace(cfg, moe_int8_dispatch=True)
    l_i8, _, aux = api.apply(params, cfg_i8, toks, mode="train")
    rel = float(jnp.linalg.norm(l_i8 - l_ref) / jnp.linalg.norm(l_ref))
    assert rel < 0.03, rel
    # gradients flow through the straight-through estimator
    from repro.training.train_step import make_loss_fn

    loss_fn = make_loss_fn(api, cfg_i8, None)
    (_, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
        params, {"tokens": toks, "labels": toks})
    gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
