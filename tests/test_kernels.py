"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed in interpret mode (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integer_scale as isc
from repro.core import packing, quant
from repro.kernels import ref as KR
from repro.kernels.act_quant import act_quant
from repro.kernels.w4a8_gemm import fg_gemm_integer_scale
from repro.kernels.w4a8_gemm_fscale import fg_gemm_float_scale
from repro.kernels.w4a16_gemm import w4a16_gemm

jax.config.update("jax_platform_name", "cpu")

SHAPES = [  # (M, K, N, group)
    (1, 256, 128, 128),     # decode-like
    (7, 512, 256, 128),     # ragged M
    (48, 1024, 512, 128),
    (16, 512, 384, 256),    # larger group
    (128, 384, 128, 128),   # K not multiple of bk default
]


def _mk(seed, M, K, N, g, w_bits=4):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K))
    qw = quant.quantize_weight(w, w_bits, g)
    xq, sa = quant.quantize_activation(x)
    packed = packing.pack_int4(qw.qvalue) if w_bits == 4 else qw.qvalue
    return qw, packed, xq, sa


@pytest.mark.parametrize("M,K,N,g", SHAPES)
def test_is_kernel_bit_exact_vs_oracle(M, K, N, g):
    qw, packed, xq, sa = _mk(0, M, K, N, g)
    isw = isc.integerize(qw, 1024)
    y_k = fg_gemm_integer_scale(xq, sa, packed, isw.int_scale,
                                group_size=g, alpha=1024.0, interpret=True)
    y_r = KR.fg_gemm_is_ref(xq, sa, packed, isw.int_scale,
                            group_size=g, alpha=1024.0)
    # integer path is bit-exact; final f32 epilogue is one multiply
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("M,K,N,g", SHAPES)
def test_fs_kernel_vs_oracle(M, K, N, g):
    qw, packed, xq, sa = _mk(1, M, K, N, g)
    y_k = fg_gemm_float_scale(xq, sa, packed, qw.scale,
                              group_size=g, interpret=True)
    y_r = KR.fg_gemm_fs_ref(xq, sa, packed, qw.scale, group_size=g)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(1, 256, 128), (33, 512, 256)])
def test_coarse_fs_kernel_vs_oracle(M, K, N):
    qw, packed, xq, sa = _mk(2, M, K, N, -1)
    y_k = fg_gemm_float_scale(xq, sa, packed, qw.scale[None, :],
                              group_size=-1, interpret=True)
    y_r = KR.fg_gemm_fs_ref(xq, sa, packed, qw.scale[None, :],
                            group_size=-1)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("M,K,N,g", SHAPES[:3])
def test_w8_is_kernel_vs_oracle(M, K, N, g):
    qw, packed, xq, sa = _mk(3, M, K, N, g, w_bits=8)
    isw = isc.integerize(qw, "heuristic+6")
    y_k = fg_gemm_integer_scale(xq, sa, packed, isw.int_scale,
                                group_size=g, alpha=float(isw.alpha),
                                w_bits=8, interpret=True)
    y_r = KR.fg_gemm_is_ref(xq, sa, packed, isw.int_scale, group_size=g,
                            alpha=float(isw.alpha), w_bits=8)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("M,K,N,g", SHAPES[:3])
def test_w4a16_kernel_vs_oracle(M, K, N, g):
    key = jax.random.PRNGKey(5)
    w = jax.random.normal(key, (K, N)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(6), (M, K)).astype(
        jnp.bfloat16)
    qw = quant.quantize_weight(w, 4, g)
    packed = packing.pack_int4(qw.qvalue)
    y_k = w4a16_gemm(x, packed, qw.scale, group_size=g, interpret=True)
    y_r = KR.w4a16_gemm_ref(x, packed, qw.scale, group_size=g)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("M,K", [(1, 128), (5, 384), (64, 1024)])
@pytest.mark.parametrize("bits", [4, 8])
def test_act_quant_kernel_vs_oracle(M, K, bits):
    x = (jax.random.normal(jax.random.PRNGKey(7), (M, K)) * 3).astype(
        jnp.bfloat16)
    q_k, s_k = act_quant(x, bits=bits, interpret=True)
    q_r, s_r = KR.act_quant_ref(x, bits=bits)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-6, atol=1e-9)
    # codes may differ by 1 at exact rounding ties (fusion order)
    diff = np.abs(q_k.astype(np.int32) - q_r.astype(np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 5e-3  # rare rounding ties


def test_kernel_block_shape_sweep():
    """BlockSpec tiling must not change results."""
    M, K, N, g = 40, 1024, 512, 128
    qw, packed, xq, sa = _mk(8, M, K, N, g)
    isw = isc.integerize(qw, 1024)
    ref = KR.fg_gemm_is_ref(xq, sa, packed, isw.int_scale,
                            group_size=g, alpha=1024.0)
    for bm, bn, bk in [(8, 128, 128), (16, 256, 256), (128, 512, 1024),
                       (32, 128, 512)]:
        y = fg_gemm_integer_scale(
            xq, sa, packed, isw.int_scale, group_size=g, alpha=1024.0,
            bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref),
                                      err_msg=f"blocks={(bm, bn, bk)}")


def test_linear_apply_pallas_honors_stored_alpha():
    """Regression: the Pallas branch of linear_apply used to drop the
    stored per-layer ``alpha`` (heuristic amplifiers then rescaled by the
    qspec default 1024 — outputs wrong by alpha/1024)."""
    from repro.core.qlinear import linear_apply, quantize_linear
    from repro.core.recipe import QuantSpec

    K, N, M = 512, 256, 16
    spec = QuantSpec(amplifier="heuristic+6")
    w = jax.random.normal(jax.random.PRNGKey(11), (K, N)) * 0.03
    x = jax.random.normal(jax.random.PRNGKey(12), (M, K))
    params = quantize_linear(w, spec)
    assert float(params["alpha"]) != 1024.0, \
        "test needs a non-default amplifier to catch the fallback"
    y_ref = linear_apply(params, x.astype(jnp.float32), spec,
                         mode="reference")
    y_pal = linear_apply(params, x.astype(jnp.float32), spec,
                         mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-2)


def test_qgemm_dispatch_matches_reference_path():
    """kernels.ops.qgemm (pallas interpret) == qlinear reference path."""
    from repro.core.qlinear import linear_apply, quantize_linear
    from repro.core.recipe import QuantSpec
    from repro.kernels.ops import BlockConfig, qgemm

    K, N, M = 512, 256, 24
    spec = QuantSpec()
    w = jax.random.normal(jax.random.PRNGKey(9), (K, N)) * 0.03
    x = jax.random.normal(jax.random.PRNGKey(10), (M, K))
    params = quantize_linear(w, spec)
    y_ref = linear_apply(params, x.astype(jnp.float32), spec,
                         mode="reference")
    y_pal = qgemm(x.astype(jnp.float32), params, spec,
                  block=BlockConfig(interpret=True))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-2)
