"""Shared benchmark harness: trained model loading, PPL eval, timing.

All quality benchmarks quantize the CPU-trained ~30M ``bench_lm`` (see
examples/quickstart.py / launch.train) and evaluate perplexity on held-out
synthetic batches. Absolute numbers differ from the paper's LLaMA-2 (no
weights offline); the *relative* claims are what each table validates.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.paper_llama import bench_lm
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models.registry import ModelApi, get_model
from repro.nn import spec as S
from repro.training.optimizer import state_specs
from repro.training.train_step import cross_entropy

CKPT_DIR = os.environ.get("BENCH_CKPT", "results/bench_lm_ckpt")
_STATE: dict = {}


def provenance() -> dict:
    """Host/build provenance stamped onto benchmark JSON documents so
    BENCH_*.json trajectories are comparable across commits and machines:
    git SHA, UTC timestamp, jax/jaxlib versions, platform, backend."""
    import datetime
    import platform
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    try:
        import jaxlib

        jaxlib_ver = jaxlib.__version__
    except Exception:
        jaxlib_ver = None
    return {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "jax": jax.__version__,
        "jaxlib": jaxlib_ver,
        "platform": platform.platform(),
        "python": platform.python_version(),
        "backend": jax.default_backend(),
    }


def load_bench_model():
    """(api, cfg, fp_params) — trained if a checkpoint exists, else a
    deterministic random init (benchmarks still run, clearly labeled)."""
    if "model" in _STATE:
        return _STATE["model"]
    cfg = bench_lm()
    api = get_model(cfg)
    pspecs = api.param_specs(cfg, None)
    mgr = CheckpointManager(CKPT_DIR)
    step = mgr.latest_step() if os.path.isdir(CKPT_DIR) else None
    if step:
        tmpl = {"params": S.abstract(pspecs),
                "opt": S.abstract(state_specs(pspecs))}
        state, _ = mgr.restore(step, tmpl)
        params = state["params"]
        trained = True
    else:
        params = S.materialize(pspecs, jax.random.PRNGKey(7))
        trained = False
    _STATE["model"] = (api, cfg, params, trained)
    return _STATE["model"]


def data_cfg() -> DataConfig:
    cfg = bench_lm()
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=128, batch_size=8)


def calib_batches(n: int = 2) -> list[dict]:
    pipe = SyntheticPipeline(data_cfg())
    return [pipe.global_batch(50_000 + i) for i in range(n)]


def eval_batches(n: int = 4) -> list[dict]:
    """Held-out region of the deterministic stream (never trained on)."""
    pipe = SyntheticPipeline(data_cfg())
    return [pipe.global_batch(100_000 + i) for i in range(n)]


def perplexity(api: ModelApi, cfg, params, recipe=None,
               batches: list[dict] | None = None) -> float:
    batches = batches or eval_batches()

    @jax.jit
    def ce(params, tokens, labels):
        logits, _, _ = api.apply(params, cfg, tokens, recipe=recipe,
                                 mode="train")
        return cross_entropy(logits, labels)

    tot = 0.0
    for b in batches:
        tot += float(ce(params, jnp.asarray(b["tokens"]),
                        jnp.asarray(b["labels"])))
    return float(np.exp(tot / len(batches)))


def timed(fn, *args, repeats: int = 3, warmup: int = 1):
    """Returns (result, best_us)."""
    r = None
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        best = min(best, time.perf_counter() - t0)
    return r, best * 1e6


class Report:
    """Collects `name,us_per_call,derived` rows (benchmarks.run contract)."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str):
        self.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    def dump(self) -> str:
        return "\n".join(f"{n},{u:.1f},{d}" for n, u, d in self.rows)


def make_expert_operands(E: int, K: int, N: int, group_size: int = 128,
                         *, amplifier: int | str = 1024, seed: int = 0):
    """Stacked per-expert W4 operands for the grouped MoE kernels/benches.

    Returns (qvalue (E, K/2, N) packed int8, int_scale (E, G, N) int32,
    float_scale (E, G, N) f32, alphas list[float]).
    """
    from repro.core import integer_scale as isc
    from repro.core import packing, quant

    packs, iscales, fscales, alphas = [], [], [], []
    for e in range(E):
        w = jax.random.normal(jax.random.PRNGKey(seed + e), (K, N)) * 0.05
        qw = quant.quantize_weight(w, 4, group_size)
        isw = isc.integerize(qw, amplifier)
        packs.append(packing.pack_int4(qw.qvalue))
        iscales.append(isw.int_scale)
        fscales.append(qw.scale)
        alphas.append(float(isw.alpha))
    return (jnp.stack(packs), jnp.stack(iscales), jnp.stack(fscales),
            alphas)


def simulate_routed_counts(E: int, tokens: int, top_k: int, *,
                           seed: int = 0, skew: float = 1.0) -> np.ndarray:
    """Per-expert routed-token counts from a Dirichlet-multinomial router
    proxy (deterministic). ``skew`` < 1 concentrates load on few experts —
    the regime where capacity padding hurts most."""
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.full(E, skew))
    return rng.multinomial(tokens * top_k, p).astype(np.int64)


def capacity_for(tokens: int, top_k: int, E: int, cf: float) -> int:
    """Per-expert capacity at factor ``cf`` — the model's own formula."""
    from repro.models.moe import capacity

    return capacity(tokens, top_k, E, cf)


def ragged_vs_dense_proxy(report, prefix: str, E: int, C: int, K: int,
                          N: int, counts, group_size: int = 128,
                          bm: int = 128) -> None:
    """CPU-proxy timing + parity: ragged scalar-prefetch kernel (fused
    act-quant, m-tile skipping) vs the dense capacity-padded grouped kernel
    (external act_quant), both interpret mode on identical ragged buffers.

    Interpret mode emulates the kernels instruction-by-instruction, so the
    wall-clock ratio reflects skipped work structurally, not TPU time. The
    bit-exact parity and the m-tile counts are the claims that transfer.
    """
    from repro.kernels.act_quant import act_quant
    from repro.kernels.moe_gemm import (fg_grouped_gemm_integer_scale,
                                        fg_grouped_gemm_integer_scale_ragged,
                                        ragged_tile_stats)

    qv, sc, _, _ = make_expert_operands(E, K, N, group_size)
    counts = [min(int(c), C) for c in counts]
    x = jax.random.normal(jax.random.PRNGKey(99), (E, C, K))
    mask = jnp.arange(C)[None, :, None] < jnp.asarray(counts)[:, None, None]
    x = jnp.where(mask, x, 0.0)
    rc = jnp.asarray(counts, jnp.int32)

    def dense(xv):
        xq, sa = act_quant(xv.reshape(E * C, K), interpret=True)
        return fg_grouped_gemm_integer_scale(
            xq.reshape(E, C, K), sa.reshape(E, C, 1), qv, sc,
            group_size=group_size, alpha=1024.0, bm=bm, interpret=True)

    def ragged(xv, rcv):
        return fg_grouped_gemm_integer_scale_ragged(
            xv, rcv, qv, sc, group_size=group_size, alpha=1024.0, bm=bm,
            interpret=True)

    y_d, us_d = timed(jax.jit(dense), x, repeats=2)
    y_r, us_r = timed(jax.jit(ragged), x, rc, repeats=2)
    exact = bool(jnp.array_equal(y_d, y_r))
    stats = ragged_tile_stats(counts, C, bm)
    report.add(f"{prefix}/dense-grouped", us_d,
               f"CPU-proxy;E={E};C={C};K={K};N={N};"
               f"m_tiles={stats['dense_m_tiles']}")
    report.add(f"{prefix}/ragged-grouped", us_r,
               f"CPU-proxy;m_tiles={stats['ragged_m_tiles']};"
               f"bm={stats['bm']};bit_exact_vs_dense={exact}")


def grouped_vs_vmapped_proxy(report, prefix: str, E: int, C: int, K: int,
                             N: int, group_size: int = 128) -> None:
    """CPU-proxy timing + parity: grouped integer-scale Pallas kernel
    (interpret) vs the vmapped per-expert reference GEMM.

    Interpret mode emulates the TPU kernel instruction-by-instruction while
    the vmapped jnp path compiles natively, so absolute times are
    structure/bookkeeping only — the bit-exact parity is the claim that
    transfers to TPU.
    """
    from repro.core import quant
    from repro.kernels.moe_gemm import fg_grouped_gemm_integer_scale
    from repro.kernels.ref import fg_gemm_is_ref

    qv, sc, _, _ = make_expert_operands(E, K, N, group_size)
    x = jax.random.normal(jax.random.PRNGKey(99), (E, C, K))
    xq, sa = quant.quantize_activation(x.reshape(E * C, K))
    xq, sa = xq.reshape(E, C, K), sa.reshape(E, C, 1)

    f_g = jax.jit(lambda a, s: fg_grouped_gemm_integer_scale(
        a, s, qv, sc, group_size=group_size, alpha=1024.0, interpret=True))
    f_v = jax.jit(lambda a, s: jax.vmap(
        lambda ae, se, qe, sce: fg_gemm_is_ref(
            ae, se, qe, sce, group_size=group_size, alpha=1024.0))(
                a, s, qv, sc))
    y_g, us_g = timed(f_g, xq, sa, repeats=2)
    y_v, us_v = timed(f_v, xq, sa, repeats=2)
    exact = bool(jnp.array_equal(y_g, y_v))
    report.add(f"{prefix}/grouped-pallas-interpret", us_g,
               f"CPU-proxy;E={E};C={C};K={K};N={N}")
    report.add(f"{prefix}/vmapped-reference", us_v,
               f"CPU-proxy;bit_exact_vs_grouped={exact}")
