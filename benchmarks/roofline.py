"""Roofline analysis (§Roofline): three terms per (arch x shape), single-pod.

    compute term    = FLOPs/chip       / peak_FLOP/s      (197 TF bf16)
    memory term     = HBM_bytes/chip   / HBM_bw           (819 GB/s)
    collective term = wire_bytes/chip  / link_bw          (~50 GB/s/link)

METHODOLOGY. The dry-run compiles every cell and provides
``memory_analysis`` (capacity proof), the collective inventory and convert
counts from the optimized HLO. However XLA's ``cost_analysis()`` counts
``while``-loop bodies ONCE — scan-over-layers (x88), chunked flash
attention and recurrent time-scans make raw HLO FLOPs/bytes unusable as
roofline numerators (granite train under-counts ~47x). Terms therefore
come from the analytic model (benchmarks/costmodel.py) derived from the
exact model/sharding definitions; raw HLO values are reported alongside
with their under-count ratio, and benchmarks/hlo_validation.py
cross-checks the analytic model against trip-count-corrected HLO
(layer-count extrapolation) on shallow cells.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference fwd), N_active for MoE; the
usefulness ratio MODEL_FLOPS / step FLOPs exposes remat/attention/dequant
overhead.
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES
from repro.models.registry import get_arch

from .costmodel import cell_cost

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256  # single-pod roofline


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_arch(arch)
    shp = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shp.kind == "train":
        return 6.0 * n * shp.batch * shp.seq
    if shp.kind == "prefill":
        return 2.0 * n * shp.batch * shp.seq
    return 2.0 * n * shp.batch  # decode: one token per sequence


def load_records(path: str = "results/dryrun.jsonl",
                 mesh: str = "16x16") -> list[dict]:
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("mesh") == mesh:
                recs[(r["arch"], r["shape"])] = r  # keep latest
    return list(recs.values())


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape = rec["arch"], rec["shape"]
    shp_kind = SHAPES[shape].kind
    cost = cell_cost(arch, shape)
    t_c = cost.flops / CHIPS / PEAK_BF16
    t_c_int8 = cost.flops / CHIPS / PEAK_INT8
    t_m = cost.hbm_bytes / HBM_BW          # per-chip already
    t_x = cost.coll_bytes / LINK_BW        # per-chip already
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    useful = mf / max(cost.flops, 1.0)
    step_t = max(terms.values())
    # speed-of-light step time: model FLOPs at the dtype-appropriate peak
    # vs minimal per-chip bytes at full HBM bw; zero collectives.
    peak = PEAK_BF16 if shp_kind == "train" else PEAK_INT8
    t_ideal = max(cost.ideal_flops / CHIPS / peak,
                  cost.ideal_hbm / HBM_BW)
    roofline_frac = t_ideal / max(step_t, 1e-12)
    hlo_flops = rec["cost"]["flops"]
    return {
        "arch": arch, "shape": shape,
        "compute_s": t_c, "compute_s_int8": t_c_int8,
        "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "flops_global": cost.flops,
        "useful_ratio": useful,
        "roofline_fraction": roofline_frac,
        "t_ideal_s": t_ideal,
        "t_step_s": step_t,
        "hlo_flops_per_dev": hlo_flops,
        "hlo_undercount": (cost.flops / CHIPS) / max(hlo_flops, 1.0),
        "arg_gib_per_dev": rec["memory"]["argument_bytes"] / 2**30,
        "temp_gib_per_dev": rec["memory"]["temp_bytes"] / 2**30,
        "convert_ops": rec.get("hlo_convert_count"),
        "collective_detail": {
            k: v for k, v in rec.get("collectives", {}).items()
            if isinstance(v, dict) and v.get("count", 0) > 0},
        "notes": cost.notes,
    }


def run(report, fast: bool = False,
        path: str = "results/dryrun.jsonl") -> list[dict]:
    rows = []
    for rec in sorted(load_records(path),
                      key=lambda r: (r["arch"], r["shape"])):
        a = analyze(rec)
        if a is None:
            if rec.get("status") == "skipped":
                report.add(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                           "SKIPPED:" + rec.get("reason", "")[:60])
            continue
        rows.append(a)
        report.add(
            f"roofline/{a['arch']}/{a['shape']}", 0.0,
            f"dom={a['dominant']};tc={a['compute_s']*1e3:.2f}ms;"
            f"tm={a['memory_s']*1e3:.2f}ms;tx={a['collective_s']*1e3:.2f}ms;"
            f"useful={a['useful_ratio']:.3f};"
            f"roofline_frac={a['roofline_fraction']:.3f}")
    if rows:
        os.makedirs("results", exist_ok=True)
        with open("results/roofline.json", "w") as f:
            json.dump(rows, f, indent=1)
    return rows
