"""Paper Table 5 / §5.6: the LLaMA-3 recipe for hard-to-quantize models.

LLaMA-3's difficulty at low bits comes from heavy activation/weight
outliers. We emulate it by injecting outlier channels into the trained
bench LM (scale up a few channels of down-proj inputs — the classic
outlier pattern), then compare:
    plain W4A8-FG-IS        (breaks or degrades)
    recipe: W4A8-FG-IS + W8A8 down-proj + QuaRot rotation (paper §5.6)
Validated claim: the recipe recovers most of the gap to FP.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec

from .common import Report, calib_batches, eval_batches, load_bench_model, \
    perplexity


def _inject_outliers(params, seed: int = 0, n_channels: int = 8,
                     factor: float = 30.0):
    """Scale up a few input channels of every mlp/down weight and scale
    down the matching up/gate output channels — output-preserving in FP,
    outlier-hostile for per-group quantization of activations feeding
    down (the LLaMA-3 pathology)."""
    rng = np.random.default_rng(seed)
    p = jax.tree.map(lambda a: a, params)  # shallow copy

    blocks = p["blocks"]
    mlp = dict(blocks["s0"]["mlp"])
    down = np.array(mlp["down"]["w"], np.float32)  # (L, f, d)
    up = np.array(mlp["up"]["w"], np.float32)      # (L, d, f)
    gate = np.array(mlp["gate"]["w"], np.float32)
    f = down.shape[1]
    idx = rng.choice(f, n_channels, replace=False)
    down[:, idx, :] *= factor
    up[:, :, idx] /= factor
    gate[:, :, idx] /= factor  # silu not linear: mild FP drift, ok for demo
    mlp["down"] = {**mlp["down"], "w": jnp.asarray(down, up.dtype)}
    mlp["up"] = {**mlp["up"], "w": jnp.asarray(up)}
    mlp["gate"] = {**mlp["gate"], "w": jnp.asarray(gate)}
    blocks = {**blocks, "s0": {**blocks["s0"], "mlp": mlp}}
    return {**p, "blocks": blocks}


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, _ = load_bench_model()
    ev = eval_batches(2 if fast else 4)
    cal = calib_batches(1)
    params_o = _inject_outliers(params)
    base = perplexity(api, cfg, params_o, batches=ev)
    report.add("table5/fp-outlier-model", 0.0, f"ppl={base:.3f}")

    plain = QuantRecipe(rules=(("*", QuantSpec()),), name="plain-w4a8")
    qp = ptq.post_training_quantize(api, cfg, params_o, plain, cal)
    ppl_plain = perplexity(api, cfg, qp, recipe=plain, batches=ev)
    report.add("table5/plain-w4a8-is", 0.0,
               f"ppl={ppl_plain:.3f};delta={ppl_plain-base:+.3f}")

    recipe = QuantRecipe(
        rules=(
            ("*down*", QuantSpec(w_bits=8, amplifier="heuristic+6",
                                 rotate=True)),
            ("*", QuantSpec(rotate=True)),
        ),
        name="llama3-recipe")
    qp = ptq.post_training_quantize(api, cfg, params_o, recipe, cal)
    ppl_recipe = perplexity(api, cfg, qp, recipe=recipe, batches=ev)
    report.add("table5/recipe-w4a8+w8down+quarot", 0.0,
               f"ppl={ppl_recipe:.3f};delta={ppl_recipe-base:+.3f};"
               f"recovered={ppl_plain-ppl_recipe:+.3f}")
