"""Paper Table 1: fine granularity consistently beats coarse quantization.

Grid: {RTN, SmoothQuant, GPTQ, Odyssey-coarse-W4A8, QuaRot-W4A4} x
{coarse (-1), fine (128)} on the trained bench LM. Validated claim:
PPL(FG) <= PPL(coarse) per method, and RTN's low-bit collapse is rescued
by FG (the paper's LLaMA-3-70B RTN 75.05 -> 7.15 story, at our scale).
"""
from __future__ import annotations

import dataclasses

from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec

from .common import Report, calib_batches, eval_batches, load_bench_model, \
    perplexity, timed


GRID = [
    ("rtn-w8a8", QuantSpec(w_bits=8, a_bits=8, algo="rtn",
                           scale_mode="float")),
    ("smoothquant-w8a8", QuantSpec(w_bits=8, a_bits=8, algo="smoothquant",
                                   scale_mode="float")),
    ("gptq-w4a16", QuantSpec(w_bits=4, a_bits=16, algo="gptq",
                             scale_mode="float")),
    ("odyssey-w4a8", QuantSpec(w_bits=4, a_bits=8, algo="rtn",
                               scale_mode="float")),
    ("rtn-w4a8", QuantSpec(w_bits=4, a_bits=8, algo="rtn",
                           scale_mode="float")),
    ("quarot-w4a4", QuantSpec(w_bits=4, a_bits=4, algo="rtn", rotate=True,
                              scale_mode="float")),
]


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, trained = load_bench_model()
    ev = eval_batches(2 if fast else 4)
    cal = calib_batches(1 if fast else 2)
    base_ppl = perplexity(api, cfg, params, batches=ev)
    tag = "trained" if trained else "RANDOM-INIT"
    report.add(f"table1/fp-baseline[{tag}]", 0.0, f"ppl={base_ppl:.3f}")

    for name, spec in GRID:
        for gname, gs in (("coarse", -1), ("fg128", 128)):
            s = dataclasses.replace(spec, group_size=gs)
            recipe = QuantRecipe(rules=(("*", s),), name=f"{name}-{gname}")
            qp = ptq.post_training_quantize(api, cfg, params, recipe, cal)
            (_, us) = timed(
                lambda: perplexity(api, cfg, qp, recipe=recipe, batches=ev),
                repeats=1, warmup=0)
            ppl = perplexity(api, cfg, qp, recipe=recipe, batches=ev)
            report.add(f"table1/{name}/{gname}", us, f"ppl={ppl:.3f}")
