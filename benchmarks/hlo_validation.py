import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Cross-validation of the analytic cost model against trip-count-corrected
HLO (EXPERIMENTS.md §Roofline methodology).

XLA counts while-loop bodies once, so raw HLO FLOPs under-count scanned
layers. Fix by extrapolation: lower the SAME cell at L1 and L2 scanned
layers; per-layer delta = (flops(L2) - flops(L1)) / (L2 - L1); then
flops(L_full) ~= flops(L1) + (L_full - L1) * delta. Compare against
benchmarks.costmodel. (Flash-attention inner chunk loops are still counted
once inside a layer — a known ~4% residual for llama2-7b at 4k.)

    PYTHONPATH=src python -m benchmarks.hlo_validation
"""

import jax

from repro.launch.dryrun import build_cell, normalized_cost_analysis
from repro.launch.mesh import make_production_mesh


def measured_flops(arch: str, shape: str, mesh, n_layers: int) -> float:
    # UNROLLED layers: under scan, XLA counts the body once regardless of
    # trip count (the very artifact being quantified), so L1/L2 would
    # differ only by stacked-array bookkeeping. Unrolling makes HLO FLOPs
    # scale with L; flash-attention inner chunk loops remain once-counted
    # (the known residual).
    lower_fn, _ = build_cell(arch, shape, mesh, False,
                             cfg_overrides={"num_layers": n_layers,
                                            "remat": False,
                                            "scan_layers": False})
    with mesh:
        compiled = lower_fn().compile()
    return float(normalized_cost_analysis(compiled).get("flops", 0.0))


def main() -> None:
    assert len(jax.devices()) == 512
    mesh = make_production_mesh(multi_pod=False)
    arch, shape = "llama2-7b", "train_4k"
    l1, l2, lf = 2, 4, 32
    f1 = measured_flops(arch, shape, mesh, l1)
    f2 = measured_flops(arch, shape, mesh, l2)
    per_layer = (f2 - f1) / (l2 - l1)
    extrap = f1 + (lf - l1) * per_layer  # per-device

    from .costmodel import cell_cost

    # analytic model counts remat (x4/3); the extrapolation cells lowered
    # remat=False -> compare against the 3x-forward analytic value
    cost = cell_cost(arch, shape)
    analytic_per_dev = cost.flops * (3 / 4) / 256
    ratio = extrap / analytic_per_dev
    print(f"HLO flops/dev: L{l1}={f1:.3e}  L{l2}={f2:.3e}  "
          f"per-layer delta={per_layer:.3e}")
    print(f"extrapolated L{lf} = {extrap:.3e} /dev")
    print(f"analytic (no-remat) = {analytic_per_dev:.3e} /dev")
    print(f"ratio extrapolated/analytic = {ratio:.3f} "
          f"(expect ~0.9-1.1; flash inner loops = known residual)")
    return ratio


if __name__ == "__main__":
    main()
