"""Benchmark regression gate: turn two ``benchmarks.run --json``
documents into an enforced perf contract.

    PYTHONPATH=src python -m benchmarks.regression \
        --baseline results/bench_baseline.json \
        --current bench-results.json

Rows are matched by a (module/config, kernel-mode) key: the row ``name``
(e.g. ``serving-moe/ragged-is`` — module + route/kernel-mode) plus the
*identity* fields parsed from its ``derived`` string (``arch=``, shape
dims, ``bm=`` ... — everything except the measured metrics). For every
matched pair the gate fails (exit 1) when:

* a baseline row has no current counterpart (coverage silently shrank);
* the current row is an ``*/ERROR`` row;
* latency (``us_per_call``) grew beyond ``--latency-tol`` (relative);
* throughput (``tok_per_s=`` in ``derived``) fell beyond ``--tps-tol``;
* a correctness contract flipped: any ``bit_exact*=True`` became
  ``False``, or ``decode_traces`` grew (instrumentation added a
  retrace).

Independent of row matching, the CURRENT document's metric snapshots
(``metrics`` — per-module registry scopes from ``benchmarks.run``, or
one standalone snapshot) are structurally checked: any nonzero
``engine_request_outcomes_total{outcome="error"}`` and any violation of
the request conservation law (``sum(outcomes) ==
engine_requests_total{event="submitted"}``) are HARD failures — a
serving benchmark that lost or double-retired requests measured
something other than serving.

Timing tolerances default WIDE (CPU interpret-mode proxies on shared CI
runners are noisy; the contract flags order-of-magnitude cliffs and
structural drift, not jitter). New current-only rows are reported but
never fail — adding coverage is free.

Refreshing the baseline INTENTIONALLY (new kernel, config rename,
machine change): rerun the sweep on the reference machine and commit the
result, calling it out in the PR —

    PYTHONPATH=src python -m benchmarks.run --fast \
        --json results/bench_baseline.json
"""
from __future__ import annotations

import argparse
import json

#: derived-string fields that are measurements, not row identity.
MEASURED_FIELDS = frozenset({
    "tok_per_s", "us_per_call", "elapsed_s", "ticks", "tokens",
    "dense_m_tiles", "ragged_m_tiles", "m_tiles", "decode_traces",
    "ppl", "ppl_fp", "ppl_q", "delta", "best", "mean", "gbps", "flops",
    "util", "us", "ms", "s",
})


def parse_derived(derived: str) -> dict[str, str]:
    """``k=v;free-text;k2=v2`` -> {k: v} (segments without '=' ignored)."""
    out = {}
    for seg in derived.split(";"):
        if "=" in seg:
            k, v = seg.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def row_key(row: dict) -> str:
    """(module/config, kernel-mode) identity: name + sorted non-measured
    derived fields."""
    fields = parse_derived(row.get("derived", ""))
    ident = sorted((k, v) for k, v in fields.items()
                   if k not in MEASURED_FIELDS and not k.startswith("bit_"))
    return row["name"] + "".join(f";{k}={v}" for k, v in ident)


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    rows: dict[str, dict] = {}
    for row in doc.get("rows", []):
        key = row_key(row)
        n = 1
        while key in rows:  # rare: disambiguate true duplicates
            n += 1
            key = f"{row_key(row)}#{n}"
        rows[key] = row
    return rows


def _tps(row: dict) -> float | None:
    v = parse_derived(row.get("derived", "")).get("tok_per_s")
    try:
        return float(v) if v is not None else None
    except ValueError:
        return None


def compare(base: dict[str, dict], cur: dict[str, dict], *,
            latency_tol: float, tps_tol: float,
            min_us: float = 50.0) -> tuple[list[str], list[str]]:
    """Returns (failures, notes). ``min_us`` skips latency ratios on
    sub-noise-floor rows (a 5us row doubling is scheduler jitter)."""
    failures: list[str] = []
    notes: list[str] = []
    for key in sorted(base):
        b = base[key]
        c = cur.get(key)
        if b["name"].endswith("/ERROR"):
            notes.append(f"baseline row {key} is an ERROR row; skipped")
            continue
        if c is None:
            failures.append(f"row disappeared: {key}")
            continue
        # latency drift
        bu, cu = float(b.get("us_per_call", 0)), float(
            c.get("us_per_call", 0))
        if bu >= min_us and cu > 0:
            ratio = cu / bu
            tag = (f"{key}: us_per_call {bu:.1f} -> {cu:.1f} "
                   f"({ratio:.2f}x)")
            if ratio > 1.0 + latency_tol:
                failures.append("latency regression: " + tag)
            else:
                notes.append(tag)
        # throughput drift
        bt, ct = _tps(b), _tps(c)
        if bt and ct is not None:
            tag = (f"{key}: tok_per_s {bt:.2f} -> {ct:.2f} "
                   f"({ct / bt:.2f}x)")
            if ct < bt * (1.0 - tps_tol):
                failures.append("throughput regression: " + tag)
            else:
                notes.append(tag)
        # correctness / structural contract fields
        bf = parse_derived(b.get("derived", ""))
        cf = parse_derived(c.get("derived", ""))
        for k, v in bf.items():
            if k.startswith("bit_exact") and v == "True" \
                    and cf.get(k) == "False":
                failures.append(f"contract flipped: {key}: {k} "
                                f"True -> False")
        if "decode_traces" in bf and "decode_traces" in cf:
            if int(cf["decode_traces"]) > int(bf["decode_traces"]):
                failures.append(
                    f"retrace regression: {key}: decode_traces "
                    f"{bf['decode_traces']} -> {cf['decode_traces']}")
    for key in sorted(set(cur) - set(base)):
        if cur[key]["name"].endswith("/ERROR"):
            failures.append(f"current run errored: {key}: "
                            f"{cur[key].get('derived', '')}")
        else:
            notes.append(f"new row (not in baseline, ok): {key}")
    return failures, notes


def metrics_failures(doc: dict) -> list[str]:
    """Structural request-accounting checks over a document's metric
    snapshot(s). Handles both shapes: ``benchmarks.run`` writes
    ``{"metrics": {module: snapshot}}`` (one registry scope per module);
    standalone module docs (``benchmarks.serving_moe --json``) write one
    top-level snapshot (``{"metrics": {"counters": ...}}``)."""
    failures: list[str] = []
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return failures
    scopes = {"": metrics} if "counters" in metrics else metrics
    for scope, snap in sorted(scopes.items()):
        if not isinstance(snap, dict):
            continue
        c = snap.get("counters", {})
        where = f" [{scope}]" if scope else ""
        outcomes = c.get("engine_request_outcomes_total", {})
        err = outcomes.get('outcome="error"', 0)
        if err:
            failures.append(
                f"engine error outcomes{where}: "
                f'engine_request_outcomes_total{{outcome="error"}} = '
                f"{int(err)}")
        submitted = c.get("engine_requests_total", {}).get(
            'event="submitted"')
        if outcomes and submitted is not None:
            total = sum(outcomes.values())
            if total != submitted:
                failures.append(
                    f"request conservation violated{where}: "
                    f"sum(outcomes) = {int(total)} != submitted = "
                    f"{int(submitted)} (lost or double-retired requests)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on perf/contract drift between two "
                    "benchmarks.run --json documents")
    ap.add_argument("--baseline", required=True,
                    help="checked-in reference document (results/...)")
    ap.add_argument("--current", required=True,
                    help="this run's document")
    ap.add_argument("--latency-tol", type=float, default=1.0,
                    help="allowed relative us_per_call growth "
                         "(1.0 = 2x; CPU-proxy noise is large)")
    ap.add_argument("--tps-tol", type=float, default=0.5,
                    help="allowed relative tokens/s drop (0.5 = half)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip latency ratio checks below this baseline "
                         "us_per_call (noise floor)")
    ap.add_argument("--verbose", action="store_true",
                    help="print per-row comparison notes")
    args = ap.parse_args(argv)

    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    failures, notes = compare(base, cur, latency_tol=args.latency_tol,
                              tps_tol=args.tps_tol, min_us=args.min_us)
    with open(args.current) as f:
        failures += metrics_failures(json.load(f))
    if args.verbose:
        for n in notes:
            print(f"[regression] ok: {n}")
    print(f"[regression] compared {len(base)} baseline rows vs "
          f"{len(cur)} current rows "
          f"(latency_tol={args.latency_tol}, tps_tol={args.tps_tol})")
    for f in failures:
        print(f"[regression] FAIL: {f}")
    if failures:
        print(f"[regression] {len(failures)} failure(s) — if this drift "
              "is intentional, refresh results/bench_baseline.json (see "
              "module docstring)")
        return 1
    print("[regression] no regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
