"""Paper Table 7 + Fig. 4: amplifier ablation & scale distribution.

PPL across amplifiers {heuristic, 128, 512, 1024, 4096} at W4A16-FG (the
paper's Table 7 setting) — validated claims: alpha=128 degrades, >=512
plateaus, heuristic ~ fixed-1024. Fig. 4 analog: per-layer bit-shift
histogram + weight MSE between integer- and float-scale dequantization.
"""
from __future__ import annotations


import numpy as np

from repro.core import ptq
from repro.core.integer_scale import bit_shift_required, \
    integerization_weight_mse
from repro.core.quant import quantize_weight
from repro.core.recipe import QuantRecipe, QuantSpec

from .common import Report, eval_batches, load_bench_model, perplexity

# W4A8 with integer scales at various amplifiers (W4A16+IS is a no-op
# pipeline-wise: weight-only keeps float scales; the paper's Table 7 runs
# the scales through the integerization regardless — we use W4A8 so the
# integer scales are actually exercised end to end).
AMPLIFIERS = ["heuristic", 128, 512, 1024, 4096]


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, trained = load_bench_model()
    ev = eval_batches(2 if fast else 4)

    fs = QuantSpec(scale_mode="float")
    r_fs = QuantRecipe(rules=(("*", fs),), name="fs")
    qp = ptq.post_training_quantize(api, cfg, params, r_fs, None)
    ppl_fs = perplexity(api, cfg, qp, recipe=r_fs, batches=ev)
    report.add("table7/float-scale-ref", 0.0, f"ppl={ppl_fs:.3f}")

    for amp in AMPLIFIERS:
        spec = QuantSpec(scale_mode="integer", amplifier=amp)
        recipe = QuantRecipe(rules=(("*", spec),), name=f"amp-{amp}")
        qp = ptq.post_training_quantize(api, cfg, params, recipe, None)
        ppl = perplexity(api, cfg, qp, recipe=recipe, batches=ev)
        report.add(f"table7/amplifier-{amp}", 0.0,
                   f"ppl={ppl:.3f};delta_vs_fs={ppl-ppl_fs:+.3f}")

    # -- Fig. 4 (b): bit shifts required per layer ---------------------------
    shifts = []
    mses = {a: [] for a in (128, 512, 1024, 4096)}

    def walk(node):
        if isinstance(node, dict) and "w" in node and not isinstance(
                node["w"], dict) and getattr(node["w"], "ndim", 0) in (2, 3):
            ws = node["w"] if node["w"].ndim == 3 else node["w"][None]
            for wi in np.asarray(ws, np.float32):
                if wi.shape[0] % 128:
                    continue
                qw = quantize_weight(wi, 4, 128)
                shifts.append(int(bit_shift_required(qw.scale)))
                for a in mses:
                    mses[a].append(float(integerization_weight_mse(qw, a)))
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)

    walk(params)
    hist = np.bincount(np.asarray(shifts), minlength=16)[:16]
    report.add("fig4/bit-shift-histogram", 0.0,
               "counts=" + "|".join(map(str, hist.tolist())))
    for a in (128, 512, 1024, 4096):
        report.add(f"fig4/weight-mse-alpha{a}", 0.0,
                   f"mse={np.mean(mses[a]):.3e}")
