"""Paper §5.5: Integer Scale through Mixture-of-Experts.

The paper's Mixtral result: fine-grained W4A8 + IS quantizes MoE models
that are otherwise hard at low bits. Here: the phi3.5-moe smoke config
(same family: 16->4 experts top-2) with random-trained weights; claim
validated structurally: expert-parallel quantized GEMMs run end-to-end
and IS-vs-FS output deltas stay small relative to FP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec
from repro.models.registry import get_arch, get_model
from repro.nn import spec as S

from .common import Report


def run(report: Report, fast: bool = False) -> None:
    cfg = get_arch("phi3.5-moe-42b-a6.6b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (4, 64), 0,
                              cfg.vocab_size)
    logits_fp, _, _ = api.apply(params, cfg, toks, mode="train")

    outs = {}
    for name, mode in (("float", "float"), ("integer", "integer")):
        spec = QuantSpec(scale_mode=mode)
        recipe = QuantRecipe(rules=(("*", spec),), name=f"moe-{name}")
        qp = ptq.post_training_quantize(api, cfg, params, recipe, None)
        logits, _, _ = api.apply(qp, cfg, toks, recipe=recipe, mode="train")
        rel = float(jnp.linalg.norm(logits - logits_fp)
                    / jnp.linalg.norm(logits_fp))
        outs[name] = (logits, rel)
        report.add(f"moe/w4a8-{name}-scale-vs-fp", 0.0, f"relerr={rel:.4f}")
    d = float(jnp.linalg.norm(outs["integer"][0] - outs["float"][0])
              / jnp.linalg.norm(outs["float"][0]))
    report.add("moe/is-vs-fs", 0.0, f"relerr={d:.4f}")
