"""Paper §5.5: Integer Scale through Mixture-of-Experts.

The paper's Mixtral result: fine-grained W4A8 + IS quantizes MoE models
that are otherwise hard at low bits. Here: the phi3.5-moe smoke config
(same family: 16->4 experts top-2) with random-trained weights; claim
validated structurally, on BOTH expert-GEMM routes:

  * vmapped reference GEMMs (the always-available jnp path), and
  * the fused grouped Pallas kernel (kernels/moe_gemm, interpret mode on
    this CPU container) — one pallas_call over (experts, m, n, k-groups).

For each route the IS-vs-FS output delta must stay small relative to FP,
and the grouped route must agree with the vmapped route (act_quant
rounding ties are the only permitted difference). Wall-clock of grouped
(interpret) vs vmapped is reported as a labeled CPU proxy — interpret mode
is an emulator, so only the numerics claim transfers to TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ptq, qlinear
from repro.core.recipe import QuantRecipe, QuantSpec
from repro.models.registry import get_arch, get_model
from repro.nn import spec as S

from .common import Report


def run(report: Report, fast: bool = False) -> None:
    cfg = get_arch("phi3.5-moe-42b-a6.6b", smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(3))
    shape = (2, 32) if fast else (4, 64)
    toks = jax.random.randint(jax.random.PRNGKey(4), shape, 0,
                              cfg.vocab_size)
    logits_fp, _, _ = api.apply(params, cfg, toks, mode="train")

    outs: dict = {}
    qps: dict = {}
    for name, mode in (("float", "float"), ("integer", "integer")):
        spec = QuantSpec(scale_mode=mode)
        recipe = QuantRecipe(rules=(("*", spec),), name=f"moe-{name}")
        qp = ptq.post_training_quantize(api, cfg, params, recipe, None)
        qps[name] = (qp, recipe)
        logits, _, _ = api.apply(qp, cfg, toks, recipe=recipe, mode="train")
        rel = float(jnp.linalg.norm(logits - logits_fp)
                    / jnp.linalg.norm(logits_fp))
        outs[name] = (logits, rel)
        report.add(f"moe/w4a8-{name}-scale-vs-fp", 0.0, f"relerr={rel:.4f}")
    d = float(jnp.linalg.norm(outs["integer"][0] - outs["float"][0])
              / jnp.linalg.norm(outs["float"][0]))
    report.add("moe/is-vs-fs", 0.0, f"relerr={d:.4f}")

    # --- grouped Pallas route (interpret): same models, same tokens -------
    grouped: dict = {}
    with qlinear.kernel_mode("pallas_interpret"):
        for name, (qp, recipe) in qps.items():
            logits, _, _ = api.apply(qp, cfg, toks, recipe=recipe,
                                     mode="train")
            grouped[name] = logits
            rel_fp = float(jnp.linalg.norm(logits - logits_fp)
                           / jnp.linalg.norm(logits_fp))
            rel_route = float(
                jnp.linalg.norm(logits - outs[name][0])
                / jnp.linalg.norm(outs[name][0]))
            report.add(f"moe/grouped-w4a8-{name}-scale-vs-fp", 0.0,
                       f"relerr={rel_fp:.4f}")
            report.add(f"moe/grouped-vs-vmapped-{name}", 0.0,
                       f"relerr={rel_route:.4f}")
    dg = float(jnp.linalg.norm(grouped["integer"] - grouped["float"])
               / jnp.linalg.norm(grouped["float"]))
    report.add("moe/grouped-is-vs-fs", 0.0,
               f"relerr={dg:.4f};vmapped_relerr={d:.4f}")

    # --- expert-GEMM latency: grouped kernel vs vmapped reference --------
    if not fast:
        from .common import grouped_vs_vmapped_proxy

        # smoke expert dims (gate/up: d -> moe_d_ff = d)
        grouped_vs_vmapped_proxy(report, "moe/expert-gemm",
                                 cfg.num_experts, 32, cfg.d_model,
                                 cfg.d_model)

    # --- ragged dispatch: padded-vs-ragged m-tiles at capacity factors ----
    # The grouped route above already runs ragged (models.moe threads the
    # per-expert routed counts into the scalar-prefetch kernel); this
    # quantifies the skipped capacity padding at the smoke expert dims.
    from repro.kernels.moe_gemm import ragged_tile_stats

    from .common import (capacity_for, ragged_vs_dense_proxy,
                         simulate_routed_counts)

    E, top_k = cfg.num_experts, cfg.top_k
    T = 256
    counts = simulate_routed_counts(E, T, top_k, seed=5, skew=0.7)
    for cf in (1.0, 1.5, 2.0):
        C = capacity_for(T, top_k, E, cf)
        stats = ragged_tile_stats(counts, C)
        report.add(
            f"moe/ragged-tiles/cf{cf}", 0.0,
            f"E={E};C={C};bm={stats['bm']};"
            f"m_tiles_dense={stats['dense_m_tiles']};"
            f"m_tiles_ragged={stats['ragged_m_tiles']}")
    if not fast:
        C = capacity_for(T, top_k, E, 1.5)
        ragged_vs_dense_proxy(report, "moe/ragged-expert-gemm", E, C,
                              cfg.d_model, cfg.d_model, counts)
