"""Paper Table 6 + §5.7: Marlin's W4A16 vs our W4A8 Integer Scale.

Quality: W4A16-g128 (Marlin-analog weight-only) vs W4A8-g128-IS perplexity
on the trained bench LM (paper: IS is "mostly on par" with W4A16 while
decisively faster). Speed: the derived-v5e latency model at the paper's
kernel shape — W4A8-IS beats W4A16 in the compute-bound region because
int8 MXU runs at 2x bf16 (paper's "faster tensor core execution at lower
bit widths").
"""
from __future__ import annotations

from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec

from .common import Report, calib_batches, eval_batches, load_bench_model, \
    perplexity
from .kernel_latency import derived_latency


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, trained = load_bench_model()
    ev = eval_batches(2 if fast else 4)
    cal = calib_batches(1)

    w4a16 = QuantRecipe(rules=(("*", QuantSpec(a_bits=16, algo="gptq")),),
                        name="marlin-w4a16")
    qp16 = ptq.post_training_quantize(api, cfg, params, w4a16, cal)
    ppl16 = perplexity(api, cfg, qp16, recipe=w4a16, batches=ev)
    report.add("table6/gptq-w4a16-marlin-analog", 0.0, f"ppl={ppl16:.3f}")

    w4a8 = QuantRecipe(rules=(("*", QuantSpec(algo="gptq")),),
                       name="gptq-w4a8-is")
    qp8 = ptq.post_training_quantize(api, cfg, params, w4a8, cal)
    ppl8 = perplexity(api, cfg, qp8, recipe=w4a8, batches=ev)
    report.add("table6/gptq-w4a8-integer-scale", 0.0,
               f"ppl={ppl8:.3f};delta_vs_w4a16={ppl8-ppl16:+.3f}")

    # derived speed at the paper's kernel shape across batch (Fig 5a)
    for M in (16, 128, 512):
        t16 = derived_latency(M, "w4a16")["t"]
        t8 = derived_latency(M, "w4a8-is")["t"]
        report.add(f"table6/derived-speed/M{M}", 0.0,
                   f"w4a8is_over_w4a16={t16/t8:.2f}x")
