"""Paper Tables 3/4/8: Integer Scale vs Float Scale accuracy deltas.

{GPTQ, AWQ, Omniquant} x {float scale, integer scale (alpha=1024)} at
fine-grained W4A8, plus the FP baseline. Validated claim: |delta PPL|
between IS and FS is small (paper: <= ~0.1), i.e. the speedup is a free
lunch. Also reports a greedy-decode agreement rate (Table 4 analog: a
downstream behavioral metric rather than PPL).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import ptq
from repro.core.recipe import QuantRecipe, QuantSpec

from .common import Report, calib_batches, eval_batches, load_bench_model, \
    perplexity

METHODS = [
    ("gptq", QuantSpec(algo="gptq")),
    ("awq", QuantSpec(algo="awq")),
    ("omniquant", QuantSpec(algo="omniquant")),
]


def greedy_agreement(api, cfg, params_a, recipe_a, params_b, recipe_b,
                     batch) -> float:
    """Fraction of positions where two models pick the same argmax token."""

    def preds(p, r):
        logits, _, _ = api.apply(p, cfg, jnp.asarray(batch["tokens"]),
                                 recipe=r, mode="train")
        return jnp.argmax(logits, -1)

    a = preds(params_a, recipe_a)
    b = preds(params_b, recipe_b)
    return float(jnp.mean((a == b).astype(jnp.float32)))


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, trained = load_bench_model()
    ev = eval_batches(2 if fast else 4)
    cal = calib_batches(1 if fast else 2)
    base_ppl = perplexity(api, cfg, params, batches=ev)
    report.add("table3/fp16-baseline", 0.0, f"ppl={base_ppl:.3f}")

    for name, spec in METHODS:
        fs = dataclasses.replace(spec, scale_mode="float")
        r_fs = QuantRecipe(rules=(("*", fs),), name=f"{name}-fs")
        qp_fs = ptq.post_training_quantize(api, cfg, params, r_fs, cal)
        ppl_fs = perplexity(api, cfg, qp_fs, recipe=r_fs, batches=ev)

        is_ = dataclasses.replace(spec, scale_mode="integer",
                                  amplifier=1024)
        r_is = QuantRecipe(rules=(("*", is_),), name=f"{name}-is")
        qp_is = ptq.post_training_quantize(api, cfg, params, r_is, cal)
        ppl_is = perplexity(api, cfg, qp_is, recipe=r_is, batches=ev)

        agree = greedy_agreement(api, cfg, qp_fs, r_fs, qp_is, r_is, ev[0])
        d = ppl_is - ppl_fs
        report.add(f"table3/{name}/float-scale", 0.0, f"ppl={ppl_fs:.3f}")
        report.add(f"table3/{name}/integer-scale", 0.0,
                   f"ppl={ppl_is:.3f};delta={d:+.3f};greedy_agree="
                   f"{agree:.3f}")
