"""Analytic per-cell cost model: FLOPs, HBM bytes, collective bytes.

WHY THIS EXISTS (see EXPERIMENTS.md §Roofline methodology): XLA's
``compiled.cost_analysis()`` counts a ``while`` loop body ONCE — scanned
layer stacks (x88 for granite), chunked flash attention and xLSTM's
time-scan are under-counted by their trip counts, so raw HLO FLOPs are
unusable as the compute-roofline numerator. We therefore derive the three
terms analytically from the model/sharding definitions (the standard
production-roofline practice), and cross-validate against trip-count-
corrected HLO on shallow-loop cells (benchmarks/hlo_validation.py).

All quantities are GLOBAL; the roofline divides by chips. Conventions:
  * matmul M,K,N -> 2MKN FLOPs
  * train = 3x forward (+1x forward when remat) for parameter FLOPs
  * causal attention scores+AV: 4*B*S^2*Hq*hd FLOPs per layer, halved for
    causality; windowed: S*W instead of S^2
  * serving weights are W4A8 (packed int4 + int32 scales ~ 0.56 B/param);
    training weights bf16, optimizer f32.
"""
from __future__ import annotations

import dataclasses

from repro.configs.shapes import SHAPES
from repro.models.config import ModelConfig
from repro.models.registry import get_arch
from repro.models.transformer import layer_kinds as tf_kinds
from repro.models import xlstm as X
from repro.models import griffin as G


@dataclasses.dataclass
class CellCost:
    flops: float           # global FLOPs for one step (sharded evenly)
    hbm_bytes: float       # PER-CHIP HBM traffic
    coll_bytes: float      # PER-CHIP wire bytes
    ideal_flops: float = 0.0   # speed-of-light: MODEL_FLOPS
    ideal_hbm: float = 0.0     # speed-of-light per-chip bytes
    notes: str = ""


W4_BYTES = 0.5 + 4.0 / 128  # packed int4 + int32 group scale per weight
BF16 = 2
F32 = 4


def _attn_flops(cfg: ModelConfig, B, Sq, Skv, causal=True, window=None):
    hd = cfg.head_dim
    eff = min(window, Skv) if window else Skv
    f = 4.0 * B * Sq * eff * cfg.num_heads * hd
    if causal and window is None and Sq == Skv:
        f *= 0.5
    return f


def _linear_weights(cfg: ModelConfig) -> dict[str, float]:
    """Per-layer linear params by kind (for flops = 2*T*params)."""
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    out = {}
    if cfg.attention == "mla":
        r, nd_, vd = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
        qin = cfg.q_lora_rank or d
        attn = (d * cfg.q_lora_rank if cfg.q_lora_rank else 0) \
            + qin * H * (nd_ + r) + d * (cfg.kv_lora_rank + r) \
            + cfg.kv_lora_rank * H * (nd_ + vd) + H * vd * d
    else:
        attn = d * H * hd * 2 + d * Hkv * hd * 2
    out["attn"] = attn
    out["mlp"] = 3 * d * cfg.d_ff
    if cfg.num_experts:
        out["moe_active"] = 3 * d * cfg.moe_d_ff * (
            cfg.top_k + cfg.num_shared_experts)
        out["moe_total"] = 3 * d * cfg.moe_d_ff * (
            cfg.num_experts + cfg.num_shared_experts)
    out["xattn"] = d * H * hd * 2 + d * Hkv * hd * 2
    return out


def _layer_list(cfg: ModelConfig) -> list[str]:
    if cfg.family in ("dense", "moe", "vlm"):
        return tf_kinds(cfg)
    if cfg.family == "ssm":
        return X.layer_kinds(cfg)
    if cfg.family == "hybrid":
        return G.layer_kinds(cfg)
    return ["encdec"]


def _param_bytes_serving(cfg: ModelConfig) -> float:
    """Quantized weight bytes (all linears int4-packed; embeds bf16)."""
    n_lin = cfg.param_count_estimate() - 2 * cfg.vocab_size * cfg.d_model
    return n_lin * W4_BYTES + 2 * cfg.vocab_size * cfg.d_model * BF16


def _kv_bytes_per_token_layer(cfg: ModelConfig, kind: str) -> float:
    if cfg.attention == "mla":
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * BF16
    b = 1 if cfg.kv_cache_dtype == "int8" else BF16
    return 2 * cfg.num_kv_heads * cfg.head_dim * b


# ---------------------------------------------------------------------------
# Per-mode costs
# ---------------------------------------------------------------------------


def _mesh(multi_pod=False):
    return {"data": 32 if multi_pod else 16, "model": 16,
            "chips": 512 if multi_pod else 256}


def forward_flops(cfg: ModelConfig, B: int, S: int, decode_ctx=None):
    """Forward FLOPs; decode_ctx = context length for one-token decode."""
    lw = _linear_weights(cfg)
    kinds = _layer_list(cfg)
    T = B * S
    f = 0.0
    d = cfg.d_model
    for kind in kinds:
        if cfg.family == "ssm":
            di = int(d * cfg.mlstm_proj_factor)
            dh = di // cfg.num_heads
            if kind == "mlstm":
                lin = d * 2 * di + 3 * di * di + di * d
                cell = 8 * cfg.num_heads * dh * dh  # C update + read
                f += 2 * T * lin + T * cell
            else:
                dh2 = d // cfg.num_heads
                ff = -(-int(d * 4 / 3) // 128) * 128
                lin = d * 4 * d + 3 * d * ff
                rec = 2 * cfg.num_heads * dh2 * 4 * dh2
                f += 2 * T * lin + T * rec
            continue
        if cfg.family == "hybrid":
            ff = 3 * d * cfg.d_ff
            if kind == "rec":
                lin = 3 * d * d + 2 * d * d  # gate,x,out + lru wa/wi
                f += 2 * T * (lin + ff) + 10 * T * d  # scan ops
            else:
                f += 2 * T * (lw["attn"] + ff)
                f += _attn_flops(cfg, B, S, decode_ctx or S,
                                 window=cfg.window)
            continue
        if cfg.family == "audio":
            ne = cfg.num_encoder_layers or cfg.num_layers
            enc_T = B * cfg.encoder_seq
            per_enc = 4 * d * d + 2 * d * cfg.d_ff
            per_dec = 8 * d * d + 2 * d * cfg.d_ff
            f += 2 * enc_T * per_enc * ne if decode_ctx is None else 0.0
            f += 2 * T * per_dec * cfg.num_layers
            f += ne * _attn_flops(cfg, B, cfg.encoder_seq, cfg.encoder_seq,
                                  causal=False) if decode_ctx is None else 0
            f += cfg.num_layers * (
                _attn_flops(cfg, B, S, decode_ctx or S)
                + _attn_flops(cfg, B, S, cfg.encoder_seq, causal=False))
            break  # kinds handled wholesale
        # transformer families
        if kind == "cross":
            f += 2 * T * (lw["xattn"] + lw["mlp"])
            f += _attn_flops(cfg, B, S, cfg.num_image_tokens, causal=False)
        elif kind == "moe":
            f += 2 * T * (lw["attn"] + lw["moe_active"])
            f += _attn_flops(cfg, B, S, decode_ctx or S)
        else:
            f += 2 * T * (lw["attn"] + lw["mlp"])
            f += _attn_flops(cfg, B, S, decode_ctx or S)
    # embeddings + head
    f += 2 * T * cfg.d_model * cfg.vocab_size  # logits (train: all pos)
    return f


def cell_cost(arch: str, shape_name: str, multi_pod=False) -> CellCost:
    cfg = get_arch(arch)
    shp = SHAPES[shape_name]
    m = _mesh(multi_pod)
    B, S = shp.batch, shp.seq
    kinds = _layer_list(cfg)
    L = len(kinds) if kinds != ["encdec"] else (
        cfg.num_layers + (cfg.num_encoder_layers or cfg.num_layers))
    n_params = cfg.param_count_estimate()
    n_active = cfg.active_param_count()
    d = cfg.d_model

    chips = m["chips"]
    if shp.kind == "train":
        fwd = forward_flops(cfg, B, S)
        mult = 3 + (1 if cfg.remat else 0)
        flops = mult * fwd
        # per-chip HBM: FSDP-gathered params land in HBM and are read per
        # pass (fwd+bwd+remat) on EVERY chip's TP shard (params/nm); grads
        # f32 + AdamW moments on the 1/chips shard; activations batch-
        # sharded, ~14*T*d*2B per layer each way.
        nd_, nm = m["data"], m["model"]
        hbm = (3 * (n_params / nm) * BF16            # gathered param reads
               + (n_params / chips) * F32 * 4        # grads + opt updates
               + 14 * (B * S / nd_) * d * BF16 * L)  # activations
        # per-chip collectives: FSDP AG (fwd+bwd+remat) + grad RS over
        # data; TP ARs on activations (2 fwd + 2 bwd per layer)
        shard_bytes = n_params / chips
        ag = 3 * shard_bytes * BF16 * (nd_ - 1)  # receive full params 3x
        rs = shard_bytes * F32 * (nd_ - 1)
        t_loc = B * S / nd_
        ar = 4 * L * 2 * (t_loc * d * BF16) * (nm - 1) / nm
        coll = ag + rs + ar
        if cfg.num_experts:
            cap = 1.25 * cfg.top_k
            coll += 2 * L * (t_loc * d * BF16 * cap)  # a2a there+back
        ideal_hbm = ((n_params * BF16 + n_params * F32 * 4) / chips
                     + 4 * (B * S / chips) * d * BF16 * L)
        return CellCost(flops, hbm, coll,
                        ideal_flops=6.0 * n_active * B * S,
                        ideal_hbm=ideal_hbm,
                        notes="train: FSDP+TP analytic")

    if shp.kind == "prefill":
        flops = forward_flops(cfg, B, S) \
            - 2 * B * (S - 1) * d * cfg.vocab_size  # last-token logits only
        wbytes = _param_bytes_serving(cfg)
        kv = sum(_kv_bytes_per_token_layer(cfg, k) for k in kinds) * B * S
        nd_, nm = m["data"], m["model"]
        # weights replicated across data rows: each chip reads its TP shard
        hbm = (wbytes / nm + 12 * (B * S / nd_ / nm) * d * BF16 * L
               + kv / chips)
        t_loc = B * S / nd_
        coll = 2 * L * (t_loc * d * BF16) * (nm - 1) / nm  # TP ARs
        ideal_hbm = (wbytes + kv) / chips \
            + 4 * (B * S / chips) * d * BF16 * L
        return CellCost(flops, hbm, coll,
                        ideal_flops=2.0 * n_active * B * S,
                        ideal_hbm=ideal_hbm, notes="prefill: TP analytic")

    # decode: one token, context S
    flops = forward_flops(cfg, B, 1, decode_ctx=S) \
        + 2 * B * d * cfg.vocab_size
    wbytes = _param_bytes_serving(cfg)
    if cfg.family == "ssm":
        state = sum(
            (cfg.num_heads * ((int(d * cfg.mlstm_proj_factor)
                               // cfg.num_heads) ** 2) * F32)
            if k == "mlstm" else (4 * d * F32)
            for k in kinds) * B
        kv_read = state * 2  # read+write recurrent state
    elif cfg.family == "hybrid":
        kv_read = sum(
            (d * F32 * 2) if k == "rec" else
            (min(cfg.window, S) * 2 * cfg.num_kv_heads * cfg.head_dim * BF16)
            for k in kinds) * B
    else:
        kv_read = sum(_kv_bytes_per_token_layer(cfg, k) for k in kinds) \
            * B * S
        if cfg.family == "audio":
            kv_read += cfg.num_layers * B * cfg.encoder_seq * 2 \
                * cfg.num_heads * cfg.head_dim * BF16
    nd_, nm = m["data"], m["model"]
    # weights replicated across data rows: each chip reads wbytes/nm;
    # KV/state sharded over (data x model) -> /chips
    hbm = wbytes / nm + kv_read / chips
    # decode collectives: TP all-reduce of (B_loc, d) twice per layer +
    # seq-sharded attention partial-softmax reduce (small)
    b_loc = max(B / nd_, 1)
    coll = 2 * L * b_loc * d * BF16 * (nm - 1) / nm
    ideal_hbm = (wbytes + kv_read) / chips  # fully weight-sharded decode
    return CellCost(flops, hbm, coll,
                    ideal_flops=2.0 * n_active * B,
                    ideal_hbm=ideal_hbm,
                    notes="decode: TP + seq-sharded KV analytic")
