"""Render EXPERIMENTS.md from the results artifacts:
results/dryrun.jsonl, results/roofline.json, results/hillclimb.json,
results/bench_quality.log (+ static narrative).

    PYTHONPATH=src python -m benchmarks.write_experiments
"""
from __future__ import annotations

import json
import os


from .roofline import analyze, load_records


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_section() -> str:
    rows = {}
    for mesh in ("16x16", "2x16x16"):
        for r in load_records(mesh=mesh):
            rows.setdefault((r["arch"], r["shape"]), {})[mesh] = r
    out = ["## §Dry-run", "",
           "Every (arch x shape) cell lowered + compiled with "
           "`jax.jit(step).lower(...).compile()` on BOTH production meshes "
           "(16x16 = 256 chips single pod; 2x16x16 = 512 chips, `pod` axis "
           "as outer data-parallel). Training cells lower `train_step` "
           "(bf16 + AdamW, FSDP+TP); prefill/decode cells lower the "
           "quantized W4A8 **Integer Scale** serving step (the paper's "
           "deployment). `args/dev` = per-device bytes of sharded "
           "params+cache+opt-state from `memory_analysis()` — the "
           "capacity proof against 16 GiB/chip HBM (v5e).", "",
           "| arch | shape | 16x16 status | args/dev GiB | compile s | "
           "2x16x16 status | args/dev GiB |",
           "|---|---|---|---|---|---|---|"]
    for (arch, shape) in sorted(rows):
        r1 = rows[(arch, shape)].get("16x16", {})
        r2 = rows[(arch, shape)].get("2x16x16", {})

        def fmt(r):
            if r.get("status") == "ok":
                return ("ok", _fmt_bytes(r["memory"]["argument_bytes"]),
                        str(r.get("compile_s", "")))
            if r.get("status") == "skipped":
                return ("skip (long-ctx n/a)", "-", "-")
            return (r.get("status", "?"), "-", "-")

        s1, m1, c1 = fmt(r1)
        s2, m2, _ = fmt(r2)
        out.append(f"| {arch} | {shape} | {s1} | {m1} | {c1} | {s2} | "
                   f"{m2} |")
    n_ok = sum(1 for v in rows.values()
               for r in v.values() if r.get("status") == "ok")
    n_skip = sum(1 for v in rows.values()
                 for r in v.values() if r.get("status") == "skipped")
    out += ["", f"**{n_ok} cells compiled, {n_skip} documented skips, 0 "
            "errors** (skips = `long_500k` on full-softmax archs, per "
            "assignment; see DESIGN.md §5). Collective schedules and "
            "convert-op counts are parsed from each compiled HLO into "
            "`results/dryrun.jsonl`."]
    return "\n".join(out)


def roofline_section() -> str:
    rows = [analyze(r) for r in load_records()]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["## §Roofline", "",
           "Hardware: TPU v5e — 197 TF/s bf16 (394 TOP/s int8), 819 GB/s "
           "HBM, ~50 GB/s/link ICI; 256 chips (single pod).", "",
           "**Methodology.** `compiled.cost_analysis()` counts `while` "
           "bodies ONCE: scan-over-layers (x88 granite), chunked flash "
           "attention and recurrent time-scans are under-counted by their "
           "trip counts (measured up to 120x, `hlo_uc` column), so raw "
           "HLO FLOPs cannot be the compute numerator. The three terms "
           "are derived analytically (benchmarks/costmodel.py) from the "
           "exact model+sharding definitions; the compiled dry-run "
           "supplies what it measures correctly — per-device memory "
           "footprints, the collective inventory, convert counts — and "
           "the under-count ratio is reported per cell. "
           "`useful` = MODEL_FLOPS/step-FLOPs (remat/attention/dequant "
           "overhead); `rf` = speed-of-light step time (model FLOPs at "
           "dtype-peak vs minimal bytes at full HBM bw, zero collectives) "
           "/ modeled step time.", "",
           "| arch | shape | dominant | compute s | memory s | "
           "collective s | useful | rf | hlo_uc |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {r['hlo_undercount']:.0f}x |")
    out += ["", "Per-cell bottleneck notes (what would move the dominant "
            "term):",
            "- **decode cells: memory-bound** (the paper's regime). The "
            "W4A8 weights are already 4x smaller than bf16; the KV cache "
            "dominates for GQA archs -> int8 KV (see §Perf). MLA archs "
            "(minicpm3, deepseek) show the latent cache paying off: "
            "rf 0.78/0.18 with tiny absolute times.",
            "- **train cells: collective-bound** under baseline FSDP+TP "
            "-> MoE a2a compression and comm/compute overlap (§Perf).",
            "- **prefill 32k on big dense archs: compute-bound** "
            "(rf 0.73-0.82) — the healthy regime; W4A8's int8 MXU "
            "(2x bf16) is the remaining 2x headroom (compute_s_int8 in "
            "results/roofline.json).",
            "", "Full per-cell JSON (incl. collective inventories and "
            "int8-peak compute terms): `results/roofline.json`."]
    return "\n".join(out)


PERF_NARRATIVE = """
### Iteration log (hypothesis -> change -> before -> after -> verdict)

**Cell 1: qwen2-72b x decode_32k** (paper-representative: W4A8-IS serving)
- *Paper-faithful baseline*: fine-grained W4A8 Integer-Scale weights
  (int4-packed + int32 scales), bf16 KV. Terms: tm **9.62 ms** dominant
  (w4 2.52 GiB/chip + KV 5.36 GiB/chip), tc 0.59 ms, tx 0.39 ms;
  rf 0.69. The paper's own claim reproduced at the system level: weights
  already 4x smaller than bf16 -> the cache, not the weights, is the
  decode wall.
- *Iter 1 (beyond-paper, QServe-inspired)*: **int8 KV cache** (per-token-
  per-head absmax). Hypothesis: KV reads halve -> tm 9.62 -> 6.35 ms
  (1.51x). Measured: compiled args/dev **7.99 -> 5.57 GiB** (exactly the
  predicted -2.68 GiB KV halving); decode-vs-bf16KV logits relerr < 0.05
  (tests/test_models_smoke.py::test_int8_kv_cache_decode). **CONFIRMED**:
  step 9.62 -> 6.35 ms, 1.51x; new split w4 2.52 + KV 2.68 GiB.
- *Iter 2 (napkin, rejected before implementing)*: weight-gathered decode
  (shard weights over data, all-gather per layer) — ICI at 50 GB/s is 16x
  slower per byte than HBM at 819 GB/s: gathering w_l x 15/256 per chip
  costs 1.17e-3*w_l s vs the 7.6e-5*w_l s HBM read it saves. REJECTED by
  arithmetic; kept TP-replicated weights.

**Cell 2: deepseek-v2-236b x train_4k** (most collective-bound: tx 20.9 s
vs tc 3.7 s analytic; MoE a2a + TP ARs + FSDP gathers)
- *Baseline*: FSDP(data) x TP(model) x EP(model), remat, scan-over-layers.
  Compiled-HLO per-occurrence wire: AR 272.6, AG 91.2, a2a 5.5,
  permute 16.2 GiB (loop bodies counted once — structural comparison
  only).
- *Iter 1*: **int8 MoE dispatch** (DeepSeek-V3-style) with a sharding
  constraint P(data, model) on the int8 buffer. Hypothesis: dispatch a2a
  halves. Measured: a2a GREW 5.5 -> 47.7 GiB (the constraint fought
  GSPMD's permute-based dispatch layout and inserted extra reshards).
  **REFUTED.**
- *Iter 2*: same quantization WITHOUT the constraint. Measured: wire
  identical to baseline — GSPMD fused quantize+dequantize locally and
  still transported bf16. **REFUTED** (and informative: autosharding
  will not split a quant/transport/dequant pattern around a collective).
- *Iter 3*: constraint with the expert-side layout P(None, model).
  Measured: a2a unchanged, all-gather +17.6 GiB (int8 buffer replicated
  over data instead). **REFUTED.**
- *Conclusion recorded*: compressing the MoE dispatch on this mesh needs
  MANUAL communication (shard_map + explicit int8 all-to-all), beyond
  GSPMD's cost model — precisely why DeepSeek-V3 hand-writes these
  kernels. Analytic value if engineered: a2a bytes x0.5 -> tx 20.9 ->
  14.8 s (NOT claimed as achieved; left as the documented next step).
  Also studied analytically: re-balancing (data, model) = (64,4)/(8,32)
  trades AR for FSDP-AG almost 1:1 — (16,16) is already near the optimum.

**Cell 3: xlstm-1.3b x prefill_32k** (worst rf 0.044: collective-bound TP
serving of a small recurrent model + a 32768-step sequential scan)
- *Baseline*: TP rules; tx 483 ms dominant (2 ARs/layer on 268 MiB
  activation slabs); HLO wire 54.4 GiB/dev; mLSTM = 32768 sequential
  cell steps.
- *Iter 1*: **chunkwise-parallel mLSTM** (closed-form stabilizer
  m_t = F_t + max(m_0, cummax(li_s - F_s)); intra-chunk decay-masked
  attention; exact vs the step recurrence to 1e-7 —
  tests/test_hillclimb_opts.py). Measured: identical terms/wire (as
  hypothesized), sequential depth 32768 -> 128. **CONFIRMED** (latency
  structure, not a 3-term mover).
- *Iter 2*: **replicated weights + 2D token sharding** (1.3B int4 =
  0.75 GiB fits per chip; tokens batch->data, seq->model; no TP).
  Hypothesis: the 483 ms of ARs vanish. Measured: HLO wire/dev
  **54.4 -> 4.2 GiB (12.9x)**, converts 1880 -> 839, args/dev
  1.54 -> 3.47 GiB (fits). Scaling the analytic tx by the measured wire
  ratio: 483 -> ~37 ms; new dominant = compute 171 ms -> **step 483 ->
  ~171 ms (2.8x), rf 0.044 -> ~0.12.** **CONFIRMED.**
- *Bonus (train_4k side-effect)*: the naive mLSTM time-scan must save the
  (dh^2) C-state history for backprop — compiled temp/dev 21,878 GiB
  (genuinely infeasible; this is why real xLSTM kernels recompute).
  Chunked mLSTM saves only chunk summaries: temp **21,878 -> 388 GiB
  (56x)**. Remaining gap = CPU-backend buffer pessimism + intra-chunk
  states; a recompute-in-backward policy is the documented next step.

**Stopping rule**: three consecutive <5% iterations was never hit; we
stopped on budget. Confirmed beyond-paper wins: 1.51x (decode cell),
2.8x (prefill cell), 56x train-memory (xlstm); the paper-faithful
baselines are reported above for every cell.
"""


def perf_section() -> str:
    path = "results/hillclimb.json"
    if not os.path.exists(path):
        return "## §Perf\n\n(hillclimb pending — run " \
               "`python -m repro.launch.hillclimb`)"
    with open(path) as f:
        recs = json.load(f)
    out = ["## §Perf — hypothesis -> change -> measure -> validate", "",
           "Three cells selected per assignment: worst roofline fraction "
           "(xlstm prefill), most collective-bound (deepseek train), most "
           "representative of the paper's technique (qwen2 W4A8-IS "
           "decode). The **paper-faithful baseline** (fine-grained W4A8 + "
           "Integer Scale, bf16 KV, bf16 dispatch) is recorded first; "
           "optimized variants are **beyond-paper** and reported "
           "separately. Changes are verified in the re-compiled HLO "
           "(collective dtypes/bytes, memory footprints), terms from the "
           "analytic model.",
           PERF_NARRATIVE,
           "### Raw per-variant compile records", ""]
    for r in recs:
        tag = f"### {r['arch']} x {r['shape']} — `{r['variant']}`"
        out.append(tag)
        if r.get("cell_why") and "baseline" in r["variant"]:
            out.append(f"*Cell selection: {r['cell_why']}.*")
        if r.get("hypothesis"):
            out.append(f"**Hypothesis:** {r['hypothesis']}")
        if r["status"] == "ok":
            mem = r["memory"]
            cw = r.get("collectives", {})
            out.append(
                f"- compiled OK; args/dev {_fmt_bytes(mem['argument_bytes'])}"
                f" GiB, temp/dev {_fmt_bytes(mem['temp_bytes'])} GiB, "
                f"HLO wire bytes/dev "
                f"{_fmt_bytes(cw.get('total_wire_bytes', 0))} GiB, "
                f"converts {r.get('hlo_convert_count')}")
            det = {k: f"n={v['count']},GiB={v['bytes']/2**30:.3f}"
                   for k, v in cw.items()
                   if isinstance(v, dict) and v.get("count")}
            out.append(f"- collectives: {det}")
        else:
            out.append(f"- status: {r['status']}: "
                       f"{r.get('error', r.get('reason', ''))[:200]}")
        out.append("")
    return "\n".join(out)


HEADER = """# EXPERIMENTS — Integer Scale (JAX/Pallas multi-pod framework)

Reproduction environment: CPU-only container (TPU v5e is the TARGET).
Quality tables quantize a 28M LLaMA-style LM **trained here** (250 steps,
loss 6.79 -> 2.48 on the deterministic synthetic corpus; no pretrained
weights exist offline) — absolute numbers differ from the paper's
LLaMA-2, the validated claims are the paper's *relative* ones. Kernel
latency claims are **derived** (v5e roofline model + HLO structure) or
**CPU-proxy**, labeled as such, never presented as measured TPU time.

Quick map: §Paper-claims (Tables 1/3/5/7, Figs 2/3/4/5/8) -> §Dry-run ->
§Roofline -> §Perf. Raw artifacts in results/.
"""


def paper_claims_section() -> str:
    rows = []
    for path in ("bench_output.txt", "results/bench_quality.log"):
        if os.path.exists(path):
            for line in open(path):
                if line.startswith(("table", "fig", "moe", "b4")):
                    rows.append(line.strip())
            break
    out = ["## §Paper-claims (validated on the trained bench LM)", "",
           "| paper artifact | our result | validated claim |",
           "|---|---|---|"]
    claims = {
        "table1": "FG(128) PPL <= coarse PPL per method "
                  "(paper Table 1's consistent FG advantage)",
        "table3": "|dPPL(IS vs FS)| <= 0.004, greedy agreement >= 97% — "
                  "the free lunch (paper Tables 3/4: deltas ~0.0x)",
        "table5": "outlier model: plain W4A8 +0.133 PPL; recipe "
                  "(W8A8 down + QuaRot) +0.006 — recovers 95% "
                  "(paper §5.6 LLaMA-3 recipe)",
        "table7": "alpha=128 degrades (+0.128), >=512 plateaus, heuristic "
                  "~ fixed-1024 (paper Table 7)",
        "fig4": "bit-shifts concentrate at 8-9; weight-MSE(1024)=5.2e-7 "
                "in the paper's (1e-7,1e-6) band",
        "fig3": "derived v5e: W4A8-IS up to 3.9x vs fp16 with the "
                "performance cliff at the memory->compute transition "
                "(paper Fig 3/5); IS-vs-FS peak 1.26x at the cliff "
                "(TPU converts are cheaper than CUDA-core I2F — see "
                "DESIGN.md §2 hardware adaptation)",
        "fig2": "our Pallas kernels: integer-scale body has fewer "
                "convert ops than float-scale (per-group converts "
                "eliminated)",
        "table6": "GPTQ W4A8-IS within +0.002 PPL of Marlin-analog "
                  "W4A16, and 1.32x faster (derived) at M=512 where int8 "
                  "MXU wins (paper Table 6/Fig 5)",
        "fig8": "max |int32 accum| = 1e-4 of 2^31 (paper Fig 8); "
                "static worst-case bound also safe; §B.4 fallback "
                "bit-identical when no overflow",
        "moe": "IS==FS within 0.8% through expert-parallel MoE "
               "(paper §5.5 Mixtral)",
        "qserve": "dual-quant (QServe-analog) costed slower than IS at "
                  "every batch (paper §5.8)",
        "fig7": "second kernel shape 4096x4096: IS over QServe-analog "
                "2.61x (M=1) .. 1.28x (M=512) derived (paper Fig 7: "
                "'our fine and coarse kernels also outperform QServe')",
    }
    for k, v in claims.items():
        out.append(f"| {k} | see rows below | {v} |")
    out += ["", "Raw benchmark rows (name,us_per_call,derived):", "```"]
    out += rows
    out += ["```", ""]
    return "\n".join(out)


def main() -> None:
    parts = [HEADER, paper_claims_section(), dryrun_section(),
             roofline_section(), perf_section()]
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n\n".join(parts) + "\n")
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
