"""Paper Fig. 8 / §B.4: INT32 overflow audit for Integer Scale.

Per layer of the bench LM: the static worst-case accumulator bound and
the empirical max |int32 accumulation| on calibration data (computed in
int64 so saturation can't hide). Validated claim: everything stays far
below 2^31 at alpha=1024. Also exercises the §B.4 fallback
(per-group de-amplified GEMM) and checks it matches the fast path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import integer_scale as isc
from repro.core import quant

from .common import Report, calib_batches, load_bench_model
from repro.core.ptq import collect_calibration


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, _ = load_bench_model()
    cal = calib_batches(1)
    captured = collect_calibration(api, cfg, params, cal)

    worst_bound = 0
    worst_emp = 0
    n_layers = 0
    fallback_checked = False
    for path, recs in sorted(captured.items()):
        x = np.concatenate(recs, 0)[:64]
        K = x.shape[1]
        if K % 128:
            continue
        # find the matching weight by path walk
        node = params
        for part in path.split("/"):
            node = node[part]
        w = np.asarray(node["w"], np.float32)
        if w.ndim == 3:
            w = w[0]
        qw = quant.quantize_weight(jnp.asarray(w), 4, 128)
        isw = isc.integerize(qw, 1024)
        xq, sa = quant.quantize_activation(jnp.asarray(x))
        bound = isc.overflow_bound(isw)
        emp = int(isc.empirical_max_accum(xq, isw))
        worst_bound = max(worst_bound, bound)
        worst_emp = max(worst_emp, emp)
        n_layers += 1
        if not fallback_checked:
            y_fast = isc.fg_gemm_integer_scale(xq, sa, isw)
            y_safe = isc.fg_gemm_integer_scale_safe(xq, sa, isw)
            d = float(jnp.max(jnp.abs(y_fast - y_safe)))
            report.add("b4/fallback-vs-fast-maxdiff", 0.0, f"{d:.2e}")
            fallback_checked = True
        if fast and n_layers >= 4:
            break

    report.add("fig8/empirical-max-accum", 0.0,
               f"max={worst_emp};frac_of_int32={worst_emp/2**31:.4f};"
               f"layers={n_layers}")
    report.add("fig8/static-worst-case-bound", 0.0,
               f"max={worst_bound};frac_of_int32={worst_bound/2**31:.4f};"
               f"safe={worst_bound < 2**31}")
