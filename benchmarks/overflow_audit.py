"""Paper Fig. 8 / §B.4: INT32 overflow audit for Integer Scale.

Per layer of the bench LM: the static worst-case accumulator bound and
the empirical max |int32 accumulation| on calibration data (computed in
int64 so saturation can't hide). Validated claim: everything stays far
below 2^31 at alpha=1024. Also exercises the §B.4 fallback
(per-group de-amplified GEMM) and checks it matches the fast path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.analysis import certify
from repro.core import integer_scale as isc
from repro.core import quant

from .common import Report, calib_batches, load_bench_model
from repro.core.ptq import collect_calibration


def run(report: Report, fast: bool = False) -> None:
    api, cfg, params, _ = load_bench_model()
    cal = calib_batches(1)
    captured = collect_calibration(api, cfg, params, cal)

    worst_bound = 0
    worst_emp = 0
    worst_analyzer = 0.0
    analyzer_dominates = True
    n_layers = 0
    skipped: list[str] = []
    fallback_checked = False
    for path, recs in sorted(captured.items()):
        x = np.concatenate(recs, 0)[:64]
        K = x.shape[1]
        if K % 128:
            # not coverable by the g128 fine-grained kernels — count it
            # rather than silently shrinking the audit
            skipped.append(f"{path}(K={K})")
            continue
        # find the matching weight by path walk
        node = params
        for part in path.split("/"):
            node = node[part]
        w = np.asarray(node["w"], np.float32)
        if w.ndim == 3:
            w = w[0]
        qw = quant.quantize_weight(jnp.asarray(w), 4, 128)
        isw = isc.integerize(qw, 1024)
        xq, sa = quant.quantize_activation(jnp.asarray(x))
        bound = isc.overflow_bound(isw)
        emp = int(isc.empirical_max_accum(xq, isw))
        # interval-analysis bound over the traced Eq. 2 contraction — must
        # dominate the empirical max on every layer (soundness check)
        st = certify.static_accum_bound(
            np.asarray(isw.int_scale), group_size=128, w_bits=4)
        analyzer_dominates &= st >= emp
        worst_analyzer = max(worst_analyzer, st)
        worst_bound = max(worst_bound, bound)
        worst_emp = max(worst_emp, emp)
        n_layers += 1
        if not fallback_checked:
            y_fast = isc.fg_gemm_integer_scale(xq, sa, isw)
            y_safe = isc.fg_gemm_integer_scale_safe(xq, sa, isw)
            d = float(jnp.max(jnp.abs(y_fast - y_safe)))
            report.add("b4/fallback-vs-fast-maxdiff", 0.0, f"{d:.2e}")
            fallback_checked = True
        if fast and n_layers >= 4:
            break

    report.add("fig8/empirical-max-accum", 0.0,
               f"max={worst_emp};frac_of_int32={worst_emp/2**31:.4f};"
               f"layers={n_layers};skipped={len(skipped)}")
    report.add("fig8/static-worst-case-bound", 0.0,
               f"max={worst_bound};frac_of_int32={worst_bound/2**31:.4f};"
               f"safe={worst_bound < 2**31}")
    report.add("fig8/analyzer-static-bound", 0.0,
               f"max={int(worst_analyzer)};"
               f"frac_of_int32={worst_analyzer/2**31:.4f};"
               f"dominates_empirical={analyzer_dominates}")
    if skipped:
        report.add("fig8/skipped-layers", 0.0,
                   f"n={len(skipped)};" + ",".join(skipped[:8]) +
                   ("..." if len(skipped) > 8 else ""))
