"""Continuous-batching quantized-MoE serving: the paper's §5.5 analog.

    PYTHONPATH=src python -m benchmarks.serving_moe [--fast] [--json PATH]

Drives the CPU-sized Mixtral-shape config (8 experts, top-2) through
``serving/engine.py`` end-to-end — prefill, batched decode ticks, retire —
three ways:

* ``ragged-is``   grouped ragged integer-scale Pallas kernels
                  (pallas_interpret), per-tick ``row_counts`` from the live
                  routed dispatch skipping capacity-padding m-tiles;
* ``grouped-fs``  same grouped ragged kernels, float-scale epilogue;
* ``vmapped-ref`` the vmapped per-expert reference GEMM (pure jnp).

Rows report tokens/s plus per-tick executed-m-tile accounting derived from
the LIVE decode dispatch (``models.moe.start_routing_trace``), and
token-stream parity of each quantized route vs the reference route. On CPU
the Pallas routes run the interpreter (instruction-level emulation), so
absolute tokens/s is NOT a speed claim — the structural claims (identical
tokens, strictly fewer executed m-tiles on the skewed decode batch, zero
decode retraces) are what transfers to TPU.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import ptq
from repro.core.recipe import DEFAULT_RECIPE, FLOAT_SCALE_RECIPE
from repro.kernels.moe_gemm import ragged_tile_stats
from repro.models import moe
from repro.models.registry import get_arch, get_model
from repro.nn import spec as S
from repro.serving.engine import Engine, ServeConfig

from .common import Report

ARCH = "mixtral-8x7b"
N_MOE_LAYERS = 2  # mixtral-smoke: both layers are MoE


def _serve_cfg(kernel_mode: str, max_new: int) -> ServeConfig:
    return ServeConfig(max_slots=4, max_seq=64, prefill_len=8,
                       max_new_tokens=max_new, temperature=0.0,
                       kernel_mode=kernel_mode)


def _prompts(n: int, vocab: int, seed: int = 0) -> list[list[int]]:
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=8).tolist() for _ in range(n)]


def _run_route(api, cfg, qp, recipe, kernel_mode: str, max_new: int,
               trace_decode: bool):
    """One engine pass: warmup run (compiles), then a timed run.

    Returns dict with outputs (rid-ordered token lists), tokens/s, tick
    count, decode trace count, and per-tick routed counts (decode only).
    """
    sc = _serve_cfg(kernel_mode, max_new)
    trace = moe.start_routing_trace() if trace_decode else None
    try:
        eng = Engine(api, cfg, qp, sc, recipe=recipe)
        vocab = cfg.vocab_size
        # warmup: compiles prefill + decode (batch shapes are fixed)
        eng.submit(_prompts(1, vocab, seed=99)[0])
        eng.run()

        n_req = sc.max_slots  # all admit in one wave -> pure decode after
        prompts = _prompts(n_req, vocab, seed=1)
        rids = [eng.submit(p) for p in prompts]
        n0 = len(trace) if trace is not None else 0
        ticks0 = eng.ticks
        t0 = time.perf_counter()
        outs = eng.run()
        elapsed = time.perf_counter() - t0
    finally:
        if trace_decode:
            moe.stop_routing_trace()

    ticks = eng.ticks - ticks0
    n_tokens = sum(len(outs[r]) for r in rids)
    decode_counts = []
    capacity = None
    if trace is not None:
        # timed-run records: n_req prefills (N_MOE_LAYERS records each)
        # first, then N_MOE_LAYERS per decode tick
        records = trace[n0 + n_req * N_MOE_LAYERS:]
        capacity = records[0]["capacity"] if records else None
        for i in range(0, len(records), N_MOE_LAYERS):
            decode_counts.append(records[i]["counts"][0])  # G=1
    return {
        "tokens": [outs[r] for r in rids],
        "tok_per_s": n_tokens / max(elapsed, 1e-9),
        "n_tokens": n_tokens,
        "ticks": ticks,
        "decode_traces": eng.decode_traces,
        "decode_counts": decode_counts,
        "capacity": capacity,
    }


def run(report: Report, fast: bool = False) -> None:
    cfg = get_arch(ARCH, smoke=True)
    api = get_model(cfg)
    params = S.materialize(api.param_specs(cfg, None), jax.random.PRNGKey(0))
    max_new = 4 if fast else 8

    qp_is = ptq.post_training_quantize(api, cfg, params, DEFAULT_RECIPE,
                                       None)
    qp_fs = ptq.post_training_quantize(api, cfg, params, FLOAT_SCALE_RECIPE,
                                       None)

    routes = {
        "vmapped-ref": _run_route(api, cfg, qp_is, DEFAULT_RECIPE,
                                  "reference", max_new, False),
        "ragged-is": _run_route(api, cfg, qp_is, DEFAULT_RECIPE,
                                "pallas_interpret", max_new, True),
        "grouped-fs": _run_route(api, cfg, qp_fs, FLOAT_SCALE_RECIPE,
                                 "pallas_interpret", max_new, False),
    }

    ref_tokens = routes["vmapped-ref"]["tokens"]
    for name, r in routes.items():
        exact = r["tokens"] == ref_tokens
        derived = (f"CPU-proxy;arch={cfg.name};E={cfg.num_experts};"
                   f"top_k={cfg.top_k};ticks={r['ticks']};"
                   f"tokens={r['n_tokens']};tok_per_s={r['tok_per_s']:.2f};"
                   f"decode_traces={r['decode_traces']};"
                   f"bit_exact_vs_reference={exact}")
        if r["decode_counts"]:
            C = r["capacity"]
            dense = ragged = 0
            for counts in r["decode_counts"]:
                st = ragged_tile_stats([int(c) for c in counts], C)
                dense += st["dense_m_tiles"]
                ragged += st["ragged_m_tiles"]
            derived += (f";capacity={C};dense_m_tiles={dense};"
                        f"ragged_m_tiles={ragged}")
        report.add(f"serving-moe/{name}",
                   1e6 * r["n_tokens"] / max(r["tok_per_s"], 1e-9)
                   / max(r["n_tokens"], 1), derived)


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", nargs="?", const="-", default="",
                    help="write rows as JSON (path, or stdout if bare)")
    args = ap.parse_args(argv)

    from repro import obs

    from .common import provenance

    report = Report()
    run(report, fast=args.fast)
    if args.json:
        prov = provenance()
        doc = {"modules": ["serving_moe"], "fast": args.fast,
               "provenance": prov,
               "rows": [{"name": n, "us_per_call": u, "derived": d,
                         "provenance": prov}
                        for n, u, d in report.rows],
               "metrics": obs.default_registry().snapshot()}
        if args.json == "-":
            print(json.dumps(doc, indent=1))
        else:
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=1)


if __name__ == "__main__":
    main()
