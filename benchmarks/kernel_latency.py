"""Paper Figs. 1/3/5/6 analog: kernel latency — derived v5e roofline model
+ HLO structural evidence + CPU wall-clock proxy.

No TPU in this container, so three honest views (all labeled):
 1. `derived`: an analytical v5e model per GEMM path at the paper's shape
    (K=4096, N=22016) across batch M. Terms: weight/activation streaming
    (819 GB/s), MXU (394 TOPS int8 / 197 TFLOPS bf16), VPU epilogue ops
    (~2e12/s), and the FS-vs-IS structural difference: float scale keeps
    TWO accumulators (int32 partial + f32) -> half the output tile per
    VMEM budget -> ~sqrt(2) more streaming traffic, plus per-group
    converts in the hot loop. Reproduces the paper's "performance cliff"
    where W4A8 transitions memory->compute bound.
 2. `hlo-converts`: convert-op counts lowered from OUR actual Pallas
    kernels — integer scale removes the per-group I32->F32 from the loop.
 3. `cpu-proxy`: wall-clock of the jnp reference paths (CPU; relative
    structure only, never claimed as TPU time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import integer_scale as isc
from repro.core import packing, quant

from .common import Report, timed

# v5e constants (assignment): 197 TFLOP/s bf16 -> 394 TOPS int8; 819 GB/s
PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
VPU_OPS = 2.0e12  # elementwise vector ops/s (8x128 lanes, ~1GHz, 2 ALUs)
VMEM_ACC_BUDGET = 4 * 2**20  # accumulator VMEM budget per core

K, N = 4096, 22016  # paper Fig 3/5/6 shape; Fig 7 uses 4096x4096
GROUP = 128
G = K // GROUP

# Grouped (batched-expert) GEMM shapes — paper §5.5 MoE targets.
MOE_SHAPES = {  # name: (E experts, K, N) for one expert FFN projection
    "mixtral-8x7b": (8, 4096, 14336),
    "phi3.5-moe": (16, 4096, 6400),
}
MOE_TOP_K = {"mixtral-8x7b": 2, "phi3.5-moe": 2}
CAPACITY_FACTORS = (1.0, 1.25, 1.5, 2.0)


def _stream_traffic(M, w_bytes_per_elem, a_bytes_per_elem, acc_bytes,
                    K=K, N=N):
    """Bytes streamed for an (M,K)x(K,N) GEMM with output-stationary tiles
    under a fixed accumulator-VMEM budget."""
    tile_elems = VMEM_ACC_BUDGET / acc_bytes
    bm = min(M, max(8, int(np.sqrt(tile_elems * M / N))))
    bn = min(N, max(128, int(tile_elems / bm)))
    w_traffic = K * N * w_bytes_per_elem * max(1, int(np.ceil(M / bm)))
    a_traffic = M * K * a_bytes_per_elem * max(1, int(np.ceil(N / bn)))
    out_traffic = M * N * 2  # bf16 out
    return w_traffic + a_traffic + out_traffic


def derived_latency(M: int, path: str, K: int = K, N: int = N) -> dict:
    import functools

    _stream = functools.partial(_stream_traffic, K=K, N=N)
    macs = 2.0 * M * K * N
    if path == "fp16":
        t_c = macs / PEAK_BF16
        t_m = _stream(M, 2, 2, 4) / HBM_BW
        t_v = 0.0
    elif path == "w4a16":
        t_c = macs / PEAK_BF16
        t_m = _stream(M, 0.5, 2, 4) / HBM_BW
        t_v = (M * N * (K // GROUP) * 2) / VPU_OPS  # per-group W dequant
    elif path == "w4a8-fs":
        t_c = macs / PEAK_INT8
        # TWO accumulators (i32+f32) -> 8B/elem budget + per-group converts
        t_m = _stream(M, 0.5, 1, 8) / HBM_BW
        t_v = (M * N * (K // GROUP) * 2 + M * N) / VPU_OPS
    elif path == "w4a8-is":
        t_c = macs / PEAK_INT8
        t_m = _stream(M, 0.5, 1, 4) / HBM_BW
        t_v = (M * N * (K // GROUP) * 2 + M * N * 2) / VPU_OPS  # + ONE convert
    elif path == "w4a8-coarse":
        t_c = macs / PEAK_INT8
        t_m = _stream(M, 0.5, 1, 4) / HBM_BW
        t_v = (M * N * 2) / VPU_OPS
    elif path == "qserve-analog":
        # DGQ dual quantization (paper §5.8/B.2): second-level asymmetric
        # dequant = elementwise multiply + subtract per WEIGHT element on
        # vector units, every time a weight tile is consumed.
        t_c = macs / PEAK_INT8
        t_m = _stream(M, 0.5, 1, 8) / HBM_BW
        tile_elems = VMEM_ACC_BUDGET / 8
        bm = min(M, max(8, int(np.sqrt(tile_elems * M / N))))
        reuse = max(1, int(np.ceil(M / bm)))
        t_v = (K * N * 2 * reuse + M * N * (K // GROUP) * 2) / VPU_OPS
    else:
        raise ValueError(path)
    # epilogue/dequant work overlaps imperfectly with MXU: serialize VPU
    return {"t": max(t_c, t_m) + t_v, "t_c": t_c, "t_m": t_m, "t_v": t_v}


def hlo_convert_counts() -> dict:
    """Lower our actual Pallas kernels (interpret) and count converts."""
    from repro.kernels.w4a8_gemm import fg_gemm_integer_scale
    from repro.kernels.w4a8_gemm_fscale import fg_gemm_float_scale

    M2, K2, N2 = 64, 1024, 512
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K2, N2)) * 0.05
    qw = quant.quantize_weight(w, 4, GROUP)
    isw = isc.integerize(qw, 1024)
    packed = packing.pack_int4(qw.qvalue)
    xq = jnp.ones((M2, K2), jnp.int8)
    sa = jnp.ones((M2, 1), jnp.float32)

    def low(fn, *args, **kw):
        return jax.jit(lambda *a: fn(*a, **kw)).lower(*args).compile()

    c_is = low(fg_gemm_integer_scale, xq, sa, packed, isw.int_scale,
               group_size=GROUP, alpha=1024.0, interpret=True).as_text()
    c_fs = low(fg_gemm_float_scale, xq, sa, packed, qw.scale,
               group_size=GROUP, interpret=True).as_text()

    return {"is": c_is.count(" convert("), "fs": c_fs.count(" convert(")}


def grouped_hlo_convert_counts() -> dict:
    """Lower the grouped MoE kernels (interpret) and count converts — the
    grouped integer-scale kernel must keep the single-convert epilogue
    structure of the dense kernel (one per output tile, none in the loop)."""
    from repro.kernels.moe_gemm import (fg_grouped_gemm_float_scale,
                                        fg_grouped_gemm_integer_scale)

    from .common import make_expert_operands

    E, C, K2, N2 = 2, 16, 512, 256
    qv, iscale, fscale, _ = make_expert_operands(E, K2, N2, GROUP)
    xq = jnp.ones((E, C, K2), jnp.int8)
    sa = jnp.ones((E, C, 1), jnp.float32)

    def low(fn, *args, **kw):
        return jax.jit(lambda *a: fn(*a, **kw)).lower(*args).compile()

    c_is = low(fg_grouped_gemm_integer_scale, xq, sa, qv, iscale,
               group_size=GROUP, alpha=1024.0, interpret=True).as_text()
    c_fs = low(fg_grouped_gemm_float_scale, xq, sa, qv, fscale,
               group_size=GROUP, interpret=True).as_text()
    return {"is": c_is.count(" convert("), "fs": c_fs.count(" convert(")}


def grouped_derived(report: Report) -> None:
    """Derived v5e latency for the grouped expert GEMM at real MoE dims:
    the grid covers all experts in one launch, so total time is the sum of
    per-expert dense GEMMs at C tokens capacity — the structural FS-vs-IS
    and weight-only comparisons carry over per expert."""
    for name, (E, Ke, Ne) in MOE_SHAPES.items():
        for C in (16, 64, 256):
            ts = {p: derived_latency(C, p, K=Ke, N=Ne)["t"] * E
                  for p in ("w4a16", "w4a8-fs", "w4a8-is")}
            report.add(
                f"moe-grouped/derived-v5e/{name}/C{C}",
                ts["w4a8-is"] * 1e6,
                f"E={E};K={Ke};N={Ne};"
                f"fs_over_is={ts['w4a8-fs'] / ts['w4a8-is']:.2f};"
                f"w4a16_over_is={ts['w4a16'] / ts['w4a8-is']:.2f}")


def ragged_tile_counts(report: Report) -> None:
    """Paper §5.5 follow-on: executed-m-tile accounting for the ragged
    scalar-prefetch grouped kernel vs the dense capacity-padded launch, at
    Mixtral/phi-3.5-MoE expert shapes across capacity factors.

    Routed counts come from a deterministic Dirichlet-multinomial router
    proxy (mild skew — the realistic load-imbalance regime). The dense
    kernel always runs E * ceil(C/bm) m-tiles; the ragged kernel runs
    sum_e ceil(min(count_e, C)/bm). Each executed m-tile costs the full
    (N/bn, K/bk) inner grid of int8 MACs, so the tile ratio IS the MXU-work
    ratio. At capacity_factor > 1 dense strictly over-provisions, so the
    ragged count must come out lower.
    """
    from repro.kernels.moe_gemm import ragged_tile_stats

    from .common import capacity_for, simulate_routed_counts

    T = 4096  # tokens per dispatch group
    for name, (E, Ke, Ne) in MOE_SHAPES.items():
        top_k = MOE_TOP_K[name]
        counts = simulate_routed_counts(E, T, top_k, seed=17, skew=0.7)
        for cf in CAPACITY_FACTORS:
            C = capacity_for(T, top_k, E, cf)
            stats = ragged_tile_stats(counts, C)
            dense, ragged = stats["dense_m_tiles"], stats["ragged_m_tiles"]
            # derived latency scales with executed tiles (per-expert GEMM
            # cost model reused; epilogue/stream terms scale the same way)
            t_dense = derived_latency(C, "w4a8-is", K=Ke, N=Ne)["t"] * E
            t_ragged = t_dense * ragged / dense
            report.add(
                f"moe-grouped/ragged-tiles/{name}/cf{cf}",
                t_ragged * 1e6,
                f"E={E};K={Ke};N={Ne};C={C};bm={stats['bm']};"
                f"m_tiles_dense={dense};m_tiles_ragged={ragged};"
                f"tile_ratio={ragged / dense:.3f};"
                f"derived_dense_us={t_dense * 1e6:.0f}")


def ragged_cpu_proxy(report: Report) -> None:
    """Interpret-mode wall-clock + bit-exact parity of ragged vs dense
    grouped kernels on a skewed small-shape dispatch buffer. bm snaps to
    16, so the skewed counts leave most m-tiles inactive (the parity and
    tile accounting are the claims that transfer to TPU)."""
    from .common import ragged_vs_dense_proxy

    E, C, K2, N2 = 4, 64, 512, 512
    counts = [64, 23, 5, 0]  # heavy skew incl. an idle expert
    ragged_vs_dense_proxy(report, "moe-grouped/ragged-cpu-proxy",
                          E, C, K2, N2, counts, GROUP, bm=16)


def grouped_cpu_proxy(report: Report) -> None:
    """Wall-clock + parity of the grouped kernel vs the vmapped reference
    at small expert dims (shared proxy; see common.grouped_vs_vmapped_proxy
    for the CPU-vs-TPU caveats)."""
    from .common import grouped_vs_vmapped_proxy

    grouped_vs_vmapped_proxy(report, "moe-grouped/cpu-proxy", 4, 32, 512,
                             512, GROUP)


def cpu_proxy(report: Report) -> None:
    """Wall-clock of the jnp reference paths (structure proxy only)."""
    M2, K2, N2 = 64, 2048, 2048
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K2, N2)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (M2, K2))
    qw = quant.quantize_weight(w, 4, GROUP)
    isw = isc.integerize(qw, 1024)
    xq, sa = quant.quantize_activation(x)

    f_fs = jax.jit(lambda a, s: quant.fg_gemm_float_scale(a, s, qw))
    f_is = jax.jit(lambda a, s: isc.fg_gemm_integer_scale(a, s, isw))
    _, us_fs = timed(f_fs, xq, sa)
    _, us_is = timed(f_is, xq, sa)
    report.add("fig3/cpu-proxy/w4a8-float-scale", us_fs, "CPU-proxy")
    report.add("fig3/cpu-proxy/w4a8-integer-scale", us_is,
               f"CPU-proxy;ratio_fs_over_is={us_fs/us_is:.2f}")


def run(report: Report, fast: bool = False) -> None:
    paths = ["fp16", "w4a16", "w4a8-coarse", "w4a8-fs", "w4a8-is",
             "qserve-analog"]
    batches = [1, 16, 64, 128, 256, 512]
    base = {M: derived_latency(M, "fp16")["t"] for M in batches}
    for path in paths:
        for M in batches:
            d = derived_latency(M, path)
            report.add(
                f"fig3/derived-v5e/{path}/M{M}", d["t"] * 1e6,
                f"speedup_vs_fp16={base[M]/d['t']:.2f};"
                f"tc={d['t_c']*1e6:.0f}us;tm={d['t_m']*1e6:.0f}us;"
                f"tv={d['t_v']*1e6:.0f}us")
    # IS vs FS headline (paper: up to 2.3x kernel, 1.83x e2e)
    for M in batches:
        r = derived_latency(M, "w4a8-fs")["t"] / \
            derived_latency(M, "w4a8-is")["t"]
        report.add(f"fig5/derived-is-speedup/M{M}", 0.0,
                   f"fs_over_is={r:.2f}")
    # Fig 7: the paper's second kernel shape (N=4096, K=4096)
    for M in (1, 64, 512):
        t_q = derived_latency(M, "qserve-analog", K=4096, N=4096)["t"]
        t_i = derived_latency(M, "w4a8-is", K=4096, N=4096)["t"]
        report.add(f"fig7/derived-4096x4096/M{M}", t_i * 1e6,
                   f"is_over_qserve={t_q/t_i:.2f}x")
    counts = hlo_convert_counts()
    report.add("fig2/hlo-converts", 0.0,
               f"integer_scale={counts['is']};float_scale={counts['fs']}")
    grouped_derived(report)
    ragged_tile_counts(report)
    gcounts = grouped_hlo_convert_counts()
    report.add("moe-grouped/hlo-converts", 0.0,
               f"integer_scale={gcounts['is']};float_scale={gcounts['fs']}")
    if not fast:
        cpu_proxy(report)
        grouped_cpu_proxy(report)
        ragged_cpu_proxy(report)
