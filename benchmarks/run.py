"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only table1,...]
                                            [--json PATH]

Prints ``name,us_per_call,derived`` CSV rows (and a trailing summary).
``--json PATH`` additionally writes the rows as a JSON document (the
nightly CI job uploads it as a build artifact). Quality tables quantize
the CPU-trained bench LM (results/bench_lm_ckpt, produced by
examples/quickstart.py); kernel/roofline rows are derived from v5e
constants + the dry-run artifacts, labeled as such.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from .common import Report


MODULES = [
    "table1_fg_vs_coarse",
    "table3_is_vs_fs",
    "table5_recipe",
    "table6_marlin",
    "table7_amplifier",
    "kernel_latency",
    "overflow_audit",
    "moe_e2e",
    "serving_moe",
    "roofline",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer eval/calib batches")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    ap.add_argument("--json", default="",
                    help="also write results as JSON to this path")
    args = ap.parse_args(argv)

    mods = MODULES
    if args.only:
        want = set(args.only.split(","))
        mods = [m for m in MODULES if m in want or m.split("_")[0] in want]

    print("name,us_per_call,derived")
    report = Report()
    t0 = time.time()
    failures = []
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t1 = time.time()
        try:
            mod.run(report, fast=args.fast)
        except Exception as e:  # noqa: BLE001 — record, keep sweeping
            failures.append((name, repr(e)))
            report.add(f"{name}/ERROR", 0.0, repr(e)[:120])
        print(f"# {name} done in {time.time()-t1:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s, {len(report.rows)} rows",
          file=sys.stderr)
    if args.json:
        from repro import obs

        from .common import provenance

        prov = provenance()
        doc = {
            "modules": mods,
            "fast": args.fast,
            "elapsed_s": round(time.time() - t0, 1),
            "provenance": prov,
            "failures": [{"module": m, "error": e} for m, e in failures],
            "rows": [{"name": n, "us_per_call": u, "derived": d,
                      "provenance": prov}
                     for n, u, d in report.rows],
            # registry snapshot: qgemm call counts, ragged m-tiles, engine
            # tick/latency series, quantization health — everything the
            # benchmarked code ticked while running
            "metrics": obs.default_registry().snapshot(),
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
