"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only table1,...]
                                            [--json PATH]
                                            [--profile-dir DIR]

Prints ``name,us_per_call,derived`` CSV rows (and a trailing summary).
``--json PATH`` additionally writes the rows as a JSON document (the
nightly CI job uploads it as a build artifact, and
``benchmarks/regression.py`` gates two such documents against each
other). Each module runs inside its OWN ``obs.Registry`` scope, so the
per-module ``metrics`` snapshots in the JSON contain only that module's
series — no bleed from modules that ran earlier in the sweep.
``--profile-dir DIR`` wraps the sweep in a ``jax.profiler.trace``
capture window. Quality tables quantize the CPU-trained bench LM
(results/bench_lm_ckpt, produced by examples/quickstart.py);
kernel/roofline rows are derived from v5e constants + the dry-run
artifacts, labeled as such.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro import obs

from .common import Report


MODULES = [
    "table1_fg_vs_coarse",
    "table3_is_vs_fs",
    "table5_recipe",
    "table6_marlin",
    "table7_amplifier",
    "kernel_latency",
    "overflow_audit",
    "moe_e2e",
    "serving_moe",
    "roofline",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer eval/calib batches")
    ap.add_argument("--only", default="",
                    help="comma-separated module subset")
    ap.add_argument("--json", default="",
                    help="also write results as JSON to this path")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the sweep into "
                         "this directory")
    args = ap.parse_args(argv)

    mods = MODULES
    if args.only:
        want = set(args.only.split(","))
        mods = [m for m in MODULES if m in want or m.split("_")[0] in want]

    print("name,us_per_call,derived")
    report = Report()
    t0 = time.time()
    failures = []
    # one fresh registry per module: module N's snapshot must not include
    # modules 1..N-1's counts (the shared default registry accumulated
    # across the whole sweep before)
    module_metrics: dict[str, dict] = {}
    with obs.trace_window(args.profile_dir or None):
        for name in mods:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            t1 = time.time()
            with obs.use_registry(obs.Registry()) as reg:
                try:
                    mod.run(report, fast=args.fast)
                except Exception as e:  # noqa: BLE001 — record, sweep on
                    failures.append((name, repr(e)))
                    report.add(f"{name}/ERROR", 0.0, repr(e)[:120])
            module_metrics[name] = reg.snapshot()
            print(f"# {name} done in {time.time()-t1:.1f}s",
                  file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s, {len(report.rows)} rows",
          file=sys.stderr)
    if args.json:
        from .common import provenance

        prov = provenance()
        doc = {
            "modules": mods,
            "fast": args.fast,
            "elapsed_s": round(time.time() - t0, 1),
            "provenance": prov,
            "failures": [{"module": m, "error": e} for m, e in failures],
            "rows": [{"name": n, "us_per_call": u, "derived": d,
                      "provenance": prov}
                     for n, u, d in report.rows],
            # per-module registry snapshots: qgemm call counts, ragged
            # m-tiles, engine tick/latency series, quantization health —
            # exactly what each module ticked, isolated per module
            "metrics": module_metrics,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
